//! Post-training pruning core: the paper's MRP solver, the SparseGPT
//! baseline, heuristic baselines, Hessian accumulation and mask types.
//!
//! Method naming follows the paper (Sec. 4.3): a method "XY" uses Solution
//! X for the pruning mask and Solution Y for the compensation;
//! SS == SparseGPT, SM/MS/MM are the paper's contributions. Magnitude and
//! Wanda are the heuristic baselines of Tables 2/3.

pub mod baselines;
pub mod hessian;
pub mod mask;
pub mod mrp;
pub mod sparsegpt;
pub mod structured;

pub use baselines::{magnitude_prune, wanda_prune};
pub use hessian::{column_norms, HessianAccumulator};
pub use mask::{column_blocks, Mask, Sparsity};
pub use mrp::{
    compensate_m, quadratic_loss, select_24_m, select_24_s, select_unstructured_s,
    IncrementalMrp, MrpSolver,
};
pub use sparsegpt::{compensate_sequential, compensate_sequential_range, sparsegpt_prune};
pub use structured::{
    column_groups, compensate_columns, dropped_columns, group_scores, kept_columns,
    select_kept_groups, StructuredConfig,
};

use anyhow::{bail, Result};

use crate::tensor::Mat;
use crate::util::{profile, Timer};

/// Pruning method (paper Sec. 4.3 + baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Magnitude,
    Wanda,
    /// Solution-S mask + sequential Solution-S compensation (= SparseGPT).
    SS,
    /// Solution-S mask + optimal Solution-M compensation (ours).
    SM,
    /// Eq. 12 Solution-M mask + sequential compensation (ours, 2:4 only).
    MS,
    /// Eq. 12 Solution-M mask + optimal compensation (ours, 2:4 only).
    MM,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Magnitude => "magnitude",
            Method::Wanda => "wanda",
            Method::SS => "SS(sparsegpt)",
            Method::SM => "SM(ours)",
            Method::MS => "MS(ours)",
            Method::MM => "MM(ours)",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "magnitude" | "mag" => Some(Method::Magnitude),
            "wanda" => Some(Method::Wanda),
            "ss" | "sparsegpt" => Some(Method::SS),
            "sm" => Some(Method::SM),
            "ms" => Some(Method::MS),
            "mm" => Some(Method::MM),
            _ => None,
        }
    }

    /// Does this method need the full Hessian (vs only diag / nothing)?
    pub fn needs_hessian(&self) -> bool {
        !matches!(self, Method::Magnitude)
    }

    pub fn all() -> [Method; 6] {
        [Method::Magnitude, Method::Wanda, Method::SS, Method::SM, Method::MS, Method::MM]
    }
}

/// Configuration for pruning one layer (or a whole model).
#[derive(Clone, Copy, Debug)]
pub struct PruneConfig {
    pub method: Method,
    pub sparsity: Sparsity,
    /// Column block size S (None = "S=all").
    pub block_size: Option<usize>,
    /// Dampening ratio gamma (Remark 4.1; paper default 0.01).
    pub gamma: f64,
}

impl PruneConfig {
    pub fn new(method: Method, sparsity: Sparsity) -> Self {
        PruneConfig { method, sparsity, block_size: None, gamma: 0.01 }
    }

    pub fn with_block(mut self, s: Option<usize>) -> Self {
        self.block_size = s;
        self
    }

    pub fn with_gamma(mut self, g: f64) -> Self {
        self.gamma = g;
        self
    }
}

/// Outcome of pruning one layer.
#[derive(Clone, Debug)]
pub struct LayerPruneResult {
    pub mask: Mask,
    /// Eq. (12) predicted loss (MRP compensation only; else NaN).
    pub pred_loss: f64,
    pub elapsed_ms: f64,
}

/// Prune one linear layer in place (native Rust path). `acc` holds the
/// calibration Hessian for this layer's inputs. Uses the incremental MRP
/// solver; see [`prune_layer_with_solver`] to pick the reference path.
pub fn prune_layer(
    w: &mut Mat,
    acc: &HessianAccumulator,
    cfg: &PruneConfig,
) -> Result<LayerPruneResult> {
    prune_layer_with_solver(w, acc, cfg, MrpSolver::Incremental)
}

/// [`prune_layer`] with an explicit choice of blockwise Eq. 13 solver.
/// The solver only affects SM/MM compensation; masks are selected by the
/// same code on both paths, so equivalence tests can require bit-identical
/// masks.
pub fn prune_layer_with_solver(
    w: &mut Mat,
    acc: &HessianAccumulator,
    cfg: &PruneConfig,
    solver: MrpSolver,
) -> Result<LayerPruneResult> {
    if acc.dim() != w.cols {
        bail!("hessian dim {} != layer in-dim {}", acc.dim(), w.cols);
    }
    if let Sparsity::SemiStructured { n, m } = cfg.sparsity {
        if (n, m) != (2, 4) {
            bail!("only 2:4 semi-structured sparsity is wired up");
        }
        if w.cols % 4 != 0 {
            bail!("2:4 needs cols % 4 == 0, got {}", w.cols);
        }
    }
    if matches!(cfg.method, Method::MS | Method::MM)
        && matches!(cfg.sparsity, Sparsity::Unstructured { .. })
    {
        bail!("M-mask is only defined for N:M sparsity (paper Sec. 4.2.1)");
    }

    let timer = Timer::start();
    let mut pred_loss = f64::NAN;
    let mask = match cfg.method {
        Method::Magnitude => magnitude_prune(w, cfg.sparsity),
        Method::Wanda => {
            let norms = column_norms(acc);
            wanda_prune(w, &norms, cfg.sparsity)
        }
        Method::SS => {
            let (_hd, hinv) = profile("hessian.finalize", || acc.finalize(cfg.gamma));
            profile("prune.ss", || {
                sparsegpt_prune(w, &hinv, cfg.sparsity, cfg.block_size, false)
            })
        }
        Method::MS => {
            let (_hd, hinv) = profile("hessian.finalize", || acc.finalize(cfg.gamma));
            profile("prune.ms", || {
                sparsegpt_prune(w, &hinv, cfg.sparsity, cfg.block_size, true)
            })
        }
        Method::SM | Method::MM => {
            let (_hd, hinv) = profile("hessian.finalize", || acc.finalize(cfg.gamma));
            let diag = hinv.diag();
            let mut cum = Mask::new(w.rows, w.cols);
            let mut loss_total = 0.0;
            // Incremental path: per-row factors of Hinv[P, P] grow across
            // blocks instead of being re-materialized + re-factored from
            // the cumulative mask each time (the seed's O(blocks·|P|³)
            // per-row cost; see PERF.md §MRP).
            let mut inc = match solver {
                MrpSolver::Incremental => Some(IncrementalMrp::new(&hinv, w.rows)),
                MrpSolver::Reference => None,
            };
            for (c0, c1) in column_blocks(w.cols, cfg.block_size) {
                let block_mask = match (cfg.method, cfg.sparsity) {
                    (Method::SM, Sparsity::Unstructured { rate }) => {
                        select_unstructured_s(w, &diag, c0, c1, rate)
                    }
                    (Method::SM, Sparsity::SemiStructured { .. }) => {
                        select_24_s(w, &diag, c0, c1)
                    }
                    (Method::MM, _) => select_24_m(w, &hinv, c0, c1).0,
                    _ => unreachable!(),
                };
                cum.or_with(&block_mask);
                // Each call returns only this step's Eq. 12 loss (the
                // established pruned entries contribute zero rhs), so the
                // layer's predicted total is the sum across blocks.
                loss_total += profile("prune.compensate_m", || match inc.as_mut() {
                    Some(inc) => inc.compensate_block(w, &block_mask),
                    None => compensate_m(w, &cum, &hinv),
                });
            }
            pred_loss = loss_total;
            cum
        }
    };
    Ok(LayerPruneResult { mask, pred_loss, elapsed_ms: timer.elapsed_ms() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> (Mat, HessianAccumulator) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(n, m, 1.0, &mut rng);
        let x = Mat::randn(4 * m, m, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(m);
        acc.add_chunk(&x);
        (w, acc)
    }

    #[test]
    fn all_methods_produce_target_sparsity_unstructured() {
        for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM] {
            let (mut w, acc) = setup(16, 32, 1);
            let cfg = PruneConfig::new(method, Sparsity::Unstructured { rate: 0.5 });
            let res = prune_layer(&mut w, &acc, &cfg).unwrap();
            assert!(
                (res.mask.sparsity() - 0.5).abs() < 0.02,
                "{method:?}: {}",
                res.mask.sparsity()
            );
            assert!((w.sparsity() - 0.5).abs() < 0.02, "{method:?}");
        }
    }

    #[test]
    fn all_methods_produce_24_structure() {
        for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM, Method::MS, Method::MM] {
            let (mut w, acc) = setup(8, 32, 2);
            let cfg = PruneConfig::new(method, Sparsity::two_four());
            let res = prune_layer(&mut w, &acc, &cfg).unwrap();
            assert!(res.mask.check_nm(2, 4), "{method:?}");
        }
    }

    #[test]
    fn m_mask_rejected_for_unstructured() {
        let (mut w, acc) = setup(4, 16, 3);
        for method in [Method::MS, Method::MM] {
            let cfg = PruneConfig::new(method, Sparsity::Unstructured { rate: 0.5 });
            assert!(prune_layer(&mut w, &acc, &cfg).is_err());
        }
    }

    #[test]
    fn loss_ordering_matches_paper_claims() {
        // Achieved quadratic loss: SM <= SS and both beat magnitude,
        // repeated over seeds (the paper's Table 1 ordering at layer level).
        let mut sm_wins = 0;
        for seed in 0..6 {
            let (w0, acc) = setup(12, 48, 100 + seed);
            let hd = acc.damped(0.01);
            let mut losses = std::collections::HashMap::new();
            for method in [Method::Magnitude, Method::SS, Method::SM] {
                let mut w = w0.clone();
                let cfg = PruneConfig::new(method, Sparsity::Unstructured { rate: 0.5 })
                    .with_block(Some(16));
                prune_layer(&mut w, &acc, &cfg).unwrap();
                losses.insert(method.name(), quadratic_loss(&w0, &w, &hd));
            }
            let (mag, ss, sm) = (
                losses["magnitude"],
                losses["SS(sparsegpt)"],
                losses["SM(ours)"],
            );
            assert!(ss < mag, "seed {seed}: SS {ss} vs mag {mag}");
            assert!(sm < mag, "seed {seed}: SM {sm} vs mag {mag}");
            if sm <= ss * 1.001 {
                sm_wins += 1;
            }
        }
        // Masks differ slightly blockwise; require SM to win in most seeds.
        assert!(sm_wins >= 5, "SM should beat SS nearly always: {sm_wins}/6");
    }

    #[test]
    fn two_four_ordering_mm_best_group_metric() {
        // The Eq. 12 M-mask is optimal in the *group-local* metric (the
        // paper's per-group simplification; cross-group interactions can
        // reorder the full loss — Table 1's occasional MS > SS rows).
        use super::mrp::group_loss_2;
        for seed in 0..4 {
            let (w0, acc) = setup(8, 32, 200 + seed);
            let (_hd, hinv) = acc.finalize(0.01);
            let diag = hinv.diag();
            let s_mask = select_24_s(&w0, &diag, 0, 32);
            let (m_mask, _) = select_24_m(&w0, &hinv, 0, 32);
            let group_total = |mask: &Mask| -> f64 {
                let mut total = 0.0;
                for r in 0..w0.rows {
                    for g0 in (0..w0.cols).step_by(4) {
                        let cols: Vec<usize> =
                            (g0..g0 + 4).filter(|&c| mask.get(r, c)).collect();
                        total += group_loss_2(
                            w0[(r, cols[0])] as f64,
                            w0[(r, cols[1])] as f64,
                            hinv[(cols[0], cols[0])],
                            hinv[(cols[0], cols[1])],
                            hinv[(cols[1], cols[1])],
                        );
                    }
                }
                total
            };
            let (lm, ls) = (group_total(&m_mask), group_total(&s_mask));
            assert!(lm <= ls * (1.0 + 1e-9), "seed {seed}: {lm} vs {ls}");
        }
    }

    #[test]
    fn dampening_changes_result_smoothly() {
        // Larger gamma = cruder Hessian approximation = worse loss under
        // the lightly-damped metric; all runs must stay finite and the
        // mildest dampening must win against the heaviest.
        let (w0, acc) = setup(6, 24, 5);
        let hd = acc.damped(0.01);
        let mut losses = Vec::new();
        for gamma in [1e-4, 1e-2, 1e0] {
            let mut w = w0.clone();
            let cfg =
                PruneConfig::new(Method::SM, Sparsity::Unstructured { rate: 0.5 }).with_gamma(gamma);
            prune_layer(&mut w, &acc, &cfg).unwrap();
            losses.push(quadratic_loss(&w0, &w, &hd));
        }
        assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0), "{losses:?}");
        assert!(losses[0] <= losses[2], "{losses:?}");
        assert!(losses[1] <= losses[2], "{losses:?}");
    }

    #[test]
    fn incremental_solver_matches_reference() {
        // The tentpole equivalence contract: for every method/sparsity/
        // block-size combination, the incremental (growing-factor) solver
        // must produce the bit-identical mask, weights within 1e-6, and
        // matching predicted loss vs the re-factor-per-block reference.
        for seed in 0..5u64 {
            let cases: [(Method, Sparsity); 3] = [
                (Method::SM, Sparsity::Unstructured { rate: 0.5 }),
                (Method::SM, Sparsity::two_four()),
                (Method::MM, Sparsity::two_four()),
            ];
            for (method, sparsity) in cases {
                for block in [None, Some(8), Some(16)] {
                    let (w0, acc) = setup(8, 32, 300 + seed);
                    let cfg = PruneConfig::new(method, sparsity).with_block(block);
                    let mut wi = w0.clone();
                    let ri =
                        prune_layer_with_solver(&mut wi, &acc, &cfg, MrpSolver::Incremental)
                            .unwrap();
                    let mut wr = w0.clone();
                    let rr =
                        prune_layer_with_solver(&mut wr, &acc, &cfg, MrpSolver::Reference)
                            .unwrap();
                    let tag = format!("seed {seed} {method:?} {sparsity:?} block {block:?}");
                    assert_eq!(ri.mask, rr.mask, "mask differs: {tag}");
                    let d = wi.max_abs_diff(&wr);
                    assert!(d < 1e-6, "weights diverged by {d}: {tag}");
                    let denom = rr.pred_loss.abs().max(1e-12);
                    let dl = (ri.pred_loss - rr.pred_loss).abs() / denom;
                    assert!(
                        dl < 1e-6,
                        "pred_loss {} vs {}: {tag}",
                        ri.pred_loss,
                        rr.pred_loss
                    );
                    // and the contract that makes the incremental solve
                    // valid in the first place: pruned entries are hard
                    // zeros on both paths
                    for r in 0..8 {
                        for &c in &ri.mask.row_indices(r) {
                            assert_eq!(wi[(r, c)], 0.0, "{tag}");
                            assert_eq!(wr[(r, c)], 0.0, "{tag}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn method_name_roundtrip() {
        for m in Method::all() {
            let s = match m {
                Method::Magnitude => "magnitude",
                Method::Wanda => "wanda",
                Method::SS => "ss",
                Method::SM => "sm",
                Method::MS => "ms",
                Method::MM => "mm",
            };
            assert_eq!(Method::from_name(s), Some(m));
        }
        assert_eq!(Method::from_name("nope"), None);
    }
}
