//! Binary tensor stores: the repo's checkpoint formats.
//!
//! [`TensorStore`] ("ATS1") is the dense-only store — safetensors-like:
//! a little-endian header with named f32 tensors, written/read without
//! any external serialization crate. It remains the gradient container.
//!
//!   magic  b"ATS1"
//!   u32    n_entries
//!   per entry: u32 name_len | name bytes | u32 rows | u32 cols | f32 data
//!
//! [`ParamStore`] ("ATS2") is the model-parameter store: each entry is a
//! [`WeightStore`] and the on-disk format is *layout-preserving*, so a
//! pruned checkpoint keeps its CSR / packed-2:4 compression on disk and
//! loads straight back into the sparse serving path:
//!
//!   magic  b"ATS2"
//!   u32    n_entries
//!   per entry: u32 name_len | name | u8 fmt | u32 rows | u32 cols | payload
//!     fmt 0 dense:    f32 data (rows*cols)
//!     fmt 1 csr:      u32 nnz | u32 indptr (rows+1) | u32 indices | f32 values
//!     fmt 2 packed24: f32 values (rows*cols/2) | u8 meta (rows*cols/4)
//!     fmt 3 csr16:    u32 nnz | u32 indptr (rows+1) | u16 indices | f32 values
//!     fmt 4 reduced:  u32 phys_rows | u32 phys_cols | u8 flags
//!                     | [flags&1: u32 n | u32 kept_rows (n, ascending)]
//!                     | [flags&2: u32 n | u32 kept_cols (n, ascending)]
//!                     | f32 data (phys_rows*phys_cols)
//!       (header rows/cols carry the LOGICAL full shape; the payload's
//!        physical shape is what the dense matmul executes)
//!
//! `ParamStore::load` also accepts ATS1 files (all-dense), so pre-existing
//! checkpoints and model caches keep working.
//! A `meta.json` sidecar (written by the model layer) carries configs.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sparse::{Csr, Csr16, Packed24, ReducedDense, WeightStore};
use crate::tensor::Mat;

const MAGIC: &[u8; 4] = b"ATS1";
const MAGIC_V2: &[u8; 4] = b"ATS2";

/// Named tensor collection (deterministic iteration order).
#[derive(Clone, Debug, Default)]
pub struct TensorStore {
    pub tensors: BTreeMap<String, Mat>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, m: Mat) {
        self.tensors.insert(name.to_string(), m);
    }

    pub fn get(&self, name: &str) -> Result<&Mat> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Mat> {
        self.tensors.get_mut(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|m| m.data.len()).sum()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, m) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(m.rows as u32).to_le_bytes())?;
            w.write_all(&(m.cols as u32).to_le_bytes())?;
            // bulk write the f32 payload
            let bytes: Vec<u8> = m.data.iter().flat_map(|f| f.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TensorStore> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {}", path.display());
        }
        let mut store = TensorStore::new();
        for (name, m) in load_ats1_body(&mut r)? {
            store.tensors.insert(name, m);
        }
        Ok(store)
    }
}

/// Upper bound on plausible tensor elements / dimensions (2^28 f32 =
/// 1 GiB): a corrupt header fails with a clean Err instead of aborting
/// the process on a huge allocation (or overflowing the byte count).
const MAX_TENSOR_ELEMS: usize = 1 << 28;

fn check_shape(name: &str, rows: usize, cols: usize) -> Result<()> {
    if rows > MAX_TENSOR_ELEMS
        || cols > MAX_TENSOR_ELEMS
        || rows.saturating_mul(cols) > MAX_TENSOR_ELEMS
    {
        bail!("implausible tensor shape {rows}x{cols} in '{name}'");
    }
    Ok(())
}

/// Parse an ATS1 body (everything after the magic): dense named tensors.
fn load_ats1_body(r: &mut impl Read) -> Result<Vec<(String, Mat)>> {
    let n = read_u32(r)? as usize;
    let mut out = Vec::with_capacity(n.min(65_536));
    for _ in 0..n {
        let name = read_name(r)?;
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        check_shape(&name, rows, cols)?;
        let data = read_f32s(r, rows * cols)?;
        out.push((name, Mat::from_vec(rows, cols, data)));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_name(r: &mut impl Read) -> Result<String> {
    let name_len = read_u32(r)? as usize;
    if name_len > 4096 {
        bail!("implausible name length {name_len}");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    String::from_utf8(name).context("tensor name not utf-8")
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u32s(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u16s(r: &mut impl Read, n: usize) -> Result<Vec<u16>> {
    let mut bytes = vec![0u8; n * 2];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(2)
        .map(|b| u16::from_le_bytes([b[0], b[1]]))
        .collect())
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn write_u32s(w: &mut impl Write, data: &[u32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn write_u16s(w: &mut impl Write, data: &[u16]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// ParamStore: named WeightStore collection (model parameters)
// ---------------------------------------------------------------------------

/// Named [`WeightStore`] collection with deterministic iteration order —
/// the model layer's parameter container. Dense at init; the coordinator
/// swaps pruned linears to their packed layouts in place.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub tensors: BTreeMap<String, WeightStore>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a dense tensor (the init/training entry point).
    pub fn insert(&mut self, name: &str, m: Mat) {
        self.tensors.insert(name.to_string(), WeightStore::Dense(m));
    }

    /// Insert a tensor in an explicit layout.
    pub fn insert_store(&mut self, name: &str, ws: WeightStore) {
        self.tensors.insert(name.to_string(), ws);
    }

    pub fn get(&self, name: &str) -> Result<&WeightStore> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut WeightStore> {
        self.tensors.get_mut(name).with_context(|| format!("tensor '{name}' missing"))
    }

    /// Borrow a tensor that must be dense (embeddings, norms, conv) —
    /// errors rather than silently densifying, because these are never
    /// packed and a sparse layout here means a wiring bug.
    pub fn dense(&self, name: &str) -> Result<&Mat> {
        match self.get(name)? {
            WeightStore::Dense(m) => Ok(m),
            other => bail!("tensor '{name}' stored as {}, expected dense", other.format()),
        }
    }

    /// Mutable dense access, densifying a packed layout in place — the
    /// trainer's "densify on demand" entry point.
    pub fn dense_mut(&mut self, name: &str) -> Result<&mut Mat> {
        Ok(self.get_mut(name)?.dense_mut())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    /// Logical parameter count (rows · cols per tensor, layout-blind).
    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|ws| ws.n_params()).sum()
    }

    /// Actual bytes across all layouts.
    pub fn bytes(&self) -> usize {
        self.tensors.values().map(|ws| ws.bytes()).sum()
    }

    /// Bytes the same parameters would occupy densely.
    pub fn dense_bytes(&self) -> usize {
        self.tensors.values().map(|ws| ws.dense_bytes()).sum()
    }

    /// All-dense copy (the baseline side of sparse-vs-dense comparisons).
    pub fn densified(&self) -> ParamStore {
        let mut out = ParamStore::new();
        for (name, ws) in &self.tensors {
            out.insert(name, ws.to_dense());
        }
        out
    }

    /// Layout-preserving save (ATS2).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC_V2)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, ws) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            // header shape is LOGICAL: a reduced store's physical shape
            // lives in its payload, so accounting against the model
            // config stays layout-blind
            let (rows, cols) = match ws {
                WeightStore::DenseReduced(rd) => (rd.full_rows, rd.full_cols),
                _ => ws.shape(),
            };
            let fmt: u8 = match ws {
                WeightStore::Dense(_) => 0,
                WeightStore::Csr(_) => 1,
                WeightStore::Packed24(_) => 2,
                WeightStore::Csr16(_) => 3,
                WeightStore::DenseReduced(_) => 4,
            };
            w.write_all(&[fmt])?;
            w.write_all(&(rows as u32).to_le_bytes())?;
            w.write_all(&(cols as u32).to_le_bytes())?;
            match ws {
                WeightStore::Dense(m) => write_f32s(&mut w, &m.data)?,
                WeightStore::Csr(c) => {
                    w.write_all(&(c.nnz() as u32).to_le_bytes())?;
                    write_u32s(&mut w, &c.indptr)?;
                    write_u32s(&mut w, &c.indices)?;
                    write_f32s(&mut w, &c.values)?;
                }
                WeightStore::Csr16(c) => {
                    w.write_all(&(c.nnz() as u32).to_le_bytes())?;
                    write_u32s(&mut w, &c.indptr)?;
                    write_u16s(&mut w, &c.indices)?;
                    write_f32s(&mut w, &c.values)?;
                }
                WeightStore::Packed24(p) => {
                    write_f32s(&mut w, &p.values)?;
                    w.write_all(&p.meta)?;
                }
                WeightStore::DenseReduced(rd) => {
                    w.write_all(&(rd.mat.rows as u32).to_le_bytes())?;
                    w.write_all(&(rd.mat.cols as u32).to_le_bytes())?;
                    let flags = rd.kept_rows.is_some() as u8
                        | ((rd.kept_cols.is_some() as u8) << 1);
                    w.write_all(&[flags])?;
                    for kept in [&rd.kept_rows, &rd.kept_cols].into_iter().flatten() {
                        w.write_all(&(kept.len() as u32).to_le_bytes())?;
                        write_u32s(&mut w, kept)?;
                    }
                    write_f32s(&mut w, &rd.mat.data)?;
                }
            }
        }
        Ok(())
    }

    /// Load an ATS2 file, or an ATS1 file as all-dense (back-compat with
    /// pre-WeightStore checkpoints and model caches).
    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        let mut store = ParamStore::new();
        if &magic == MAGIC {
            for (name, m) in load_ats1_body(&mut r)? {
                store.tensors.insert(name, WeightStore::Dense(m));
            }
            return Ok(store);
        }
        if &magic != MAGIC_V2 {
            bail!("bad magic in {}", path.display());
        }
        let n = read_u32(&mut r)? as usize;
        for _ in 0..n {
            let name = read_name(&mut r)?;
            let mut fmt = [0u8; 1];
            r.read_exact(&mut fmt)?;
            let rows = read_u32(&mut r)? as usize;
            let cols = read_u32(&mut r)? as usize;
            check_shape(&name, rows, cols)?;
            let ws = match fmt[0] {
                0 => WeightStore::Dense(Mat::from_vec(rows, cols, read_f32s(&mut r, rows * cols)?)),
                1 => {
                    let nnz = read_u32(&mut r)? as usize;
                    if nnz > rows * cols {
                        bail!("implausible nnz {nnz} for {rows}x{cols} '{name}'");
                    }
                    let indptr = read_u32s(&mut r, rows + 1)?;
                    // indptr must start at 0, be non-decreasing, and end
                    // at nnz — otherwise row slicing panics (or silently
                    // mis-assigns weights) at first use instead of
                    // failing loudly here.
                    if indptr.first().copied().unwrap_or(1) != 0
                        || indptr.windows(2).any(|p| p[0] > p[1])
                        || indptr.last().copied().unwrap_or(0) as usize != nnz
                    {
                        bail!("csr indptr malformed in '{name}'");
                    }
                    let indices = read_u32s(&mut r, nnz)?;
                    // Per row: in range and strictly increasing (the
                    // writer emits ascending unique columns). Duplicates
                    // would make matmul_tb sum entries that to_dense
                    // last-write-wins drops — silent divergence.
                    for row in 0..rows {
                        let seg = &indices[indptr[row] as usize..indptr[row + 1] as usize];
                        if seg.iter().any(|&c| c as usize >= cols)
                            || seg.windows(2).any(|p| p[0] >= p[1])
                        {
                            bail!("csr indices malformed in '{name}' row {row}");
                        }
                    }
                    let values = read_f32s(&mut r, nnz)?;
                    WeightStore::Csr(Csr { rows, cols, indptr, indices, values })
                }
                2 => {
                    if cols % 4 != 0 {
                        bail!("packed24 cols {cols} not divisible by 4 in '{name}'");
                    }
                    let values = read_f32s(&mut r, rows * cols / 2)?;
                    let mut meta = vec![0u8; rows * cols / 4];
                    r.read_exact(&mut meta)?;
                    // Each meta byte is (i1 << 2) | i0 with distinct
                    // 2-bit indices; equal indices would make matmul_tb
                    // and to_dense disagree, like CSR duplicates.
                    if meta.iter().any(|&b| b >> 4 != 0 || b & 3 == (b >> 2) & 3) {
                        bail!("packed24 meta malformed in '{name}'");
                    }
                    WeightStore::Packed24(Packed24 { rows, cols, values, meta })
                }
                3 => {
                    if cols > Csr16::MAX_COLS {
                        bail!("csr16 cols {cols} exceed u16 index range in '{name}'");
                    }
                    let nnz = read_u32(&mut r)? as usize;
                    if nnz > rows * cols {
                        bail!("implausible nnz {nnz} for {rows}x{cols} '{name}'");
                    }
                    let indptr = read_u32s(&mut r, rows + 1)?;
                    // same indptr/index invariants as the u32 CSR arm:
                    // fail loudly at load, not at first forward
                    if indptr.first().copied().unwrap_or(1) != 0
                        || indptr.windows(2).any(|p| p[0] > p[1])
                        || indptr.last().copied().unwrap_or(0) as usize != nnz
                    {
                        bail!("csr16 indptr malformed in '{name}'");
                    }
                    let indices = read_u16s(&mut r, nnz)?;
                    for row in 0..rows {
                        let seg = &indices[indptr[row] as usize..indptr[row + 1] as usize];
                        if seg.iter().any(|&c| c as usize >= cols)
                            || seg.windows(2).any(|p| p[0] >= p[1])
                        {
                            bail!("csr16 indices malformed in '{name}' row {row}");
                        }
                    }
                    let values = read_f32s(&mut r, nnz)?;
                    WeightStore::Csr16(Csr16 { rows, cols, indptr, indices, values })
                }
                4 => {
                    let phys_rows = read_u32(&mut r)? as usize;
                    let phys_cols = read_u32(&mut r)? as usize;
                    check_shape(&name, phys_rows, phys_cols)?;
                    if phys_rows > rows || phys_cols > cols {
                        bail!(
                            "reduced physical shape {phys_rows}x{phys_cols} exceeds \
                             logical {rows}x{cols} in '{name}'"
                        );
                    }
                    let mut flags = [0u8; 1];
                    r.read_exact(&mut flags)?;
                    if flags[0] & !3 != 0 {
                        bail!("unknown reduced-store flags {:#04x} in '{name}'", flags[0]);
                    }
                    // each kept list's length must equal the physical
                    // axis — checked BEFORE the allocation so a corrupt
                    // count fails cleanly, and again structurally (range,
                    // strict ascent, presence) by ReducedDense::new
                    let mut kept = [None, None];
                    for (bit, (slot, phys)) in
                        kept.iter_mut().zip([phys_rows, phys_cols]).enumerate()
                    {
                        if flags[0] & (1 << bit) == 0 {
                            continue;
                        }
                        let n = read_u32(&mut r)? as usize;
                        let axis = if bit == 0 { "row" } else { "col" };
                        if n != phys {
                            bail!(
                                "kept-{axis} list length {n} != physical {axis}s {phys} \
                                 in '{name}'"
                            );
                        }
                        *slot = Some(read_u32s(&mut r, n)?);
                    }
                    let [kept_rows, kept_cols] = kept;
                    let data = read_f32s(&mut r, phys_rows * phys_cols)?;
                    let rd = ReducedDense::new(
                        rows,
                        cols,
                        kept_rows,
                        kept_cols,
                        Mat::from_vec(phys_rows, phys_cols, data),
                    )
                    .with_context(|| format!("reduced store '{name}'"))?;
                    WeightStore::DenseReduced(rd)
                }
                f => bail!("unknown weight format tag {f} in '{name}'"),
            };
            store.tensors.insert(name, ws);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut s = TensorStore::new();
        s.insert("layer0.wq", Mat::randn(8, 8, 1.0, &mut rng));
        s.insert("layer0.wk", Mat::randn(4, 16, 0.5, &mut rng));
        s.insert("embed", Mat::randn(32, 8, 0.02, &mut rng));
        let dir = std::env::temp_dir().join("apt_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ats");
        s.save(&path).unwrap();
        let loaded = TensorStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        for name in s.names() {
            assert_eq!(s.get(name).unwrap(), loaded.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("apt_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ats");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let s = TensorStore::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn total_params_counts() {
        let mut rng = Rng::new(2);
        let mut s = TensorStore::new();
        s.insert("a", Mat::randn(3, 4, 1.0, &mut rng));
        s.insert("b", Mat::randn(5, 2, 1.0, &mut rng));
        assert_eq!(s.total_params(), 22);
    }

    #[test]
    fn param_store_roundtrips_every_layout() {
        use crate::prune::{magnitude_prune, Sparsity};
        let mut rng = Rng::new(3);
        let mut s = ParamStore::new();
        s.insert("dense", Mat::randn(5, 8, 1.0, &mut rng));
        let mut wu = Mat::randn(6, 12, 1.0, &mut rng);
        magnitude_prune(&mut wu, Sparsity::Unstructured { rate: 0.7 });
        s.insert_store("csr", WeightStore::Csr(Csr::from_dense(&wu)));
        let mut w16 = Mat::randn(7, 20, 1.0, &mut rng);
        magnitude_prune(&mut w16, Sparsity::Unstructured { rate: 0.6 });
        s.insert_store("csr16", WeightStore::Csr16(Csr16::from_dense(&w16)));
        let mut w24 = Mat::randn(4, 16, 1.0, &mut rng);
        magnitude_prune(&mut w24, Sparsity::two_four());
        s.insert_store("packed", WeightStore::Packed24(Packed24::from_dense(&w24).unwrap()));

        let dir = std::env::temp_dir().join("apt_test_param_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ats");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 4);
        for name in s.names() {
            assert_eq!(s.get(name).unwrap(), loaded.get(name).unwrap(), "{name}");
        }
        // layouts survive, and so do the byte counts
        assert_eq!(loaded.get("csr").unwrap().format(), "csr");
        assert_eq!(loaded.get("csr16").unwrap().format(), "csr16");
        assert_eq!(loaded.get("packed").unwrap().format(), "packed24");
        assert_eq!(loaded.bytes(), s.bytes());
        assert!(loaded.bytes() < loaded.dense_bytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn param_store_loads_ats1_checkpoints() {
        let mut rng = Rng::new(4);
        let mut old = TensorStore::new();
        old.insert("embed", Mat::randn(16, 8, 0.5, &mut rng));
        old.insert("blocks.0.wq", Mat::randn(8, 8, 1.0, &mut rng));
        let dir = std::env::temp_dir().join("apt_test_param_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ats1_compat.ats");
        old.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        for name in old.names() {
            assert_eq!(loaded.get(name).unwrap().format(), "dense");
            assert_eq!(loaded.dense(name).unwrap(), old.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    /// Hand-build one ATS2 CSR entry named "w" from raw parts.
    fn ats2_csr_bytes(rows: u32, cols: u32, indptr: &[u32], indices: &[u32]) -> Vec<u8> {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"ATS2");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // n_entries
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'w');
        bytes.push(1u8); // fmt = csr
        bytes.extend_from_slice(&rows.to_le_bytes());
        bytes.extend_from_slice(&cols.to_le_bytes());
        bytes.extend_from_slice(&(indices.len() as u32).to_le_bytes()); // nnz
        for v in indptr {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in indices {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for _ in indices {
            bytes.extend_from_slice(&1.0f32.to_le_bytes()); // values
        }
        bytes
    }

    fn load_bytes(file: &str, bytes: &[u8]) -> Result<ParamStore> {
        let dir = std::env::temp_dir().join("apt_test_param_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(file);
        std::fs::write(&path, bytes).unwrap();
        let res = ParamStore::load(&path);
        std::fs::remove_file(path).ok();
        res
    }

    #[test]
    fn param_store_rejects_malformed_csr() {
        // Non-monotonic indptr that still passes the nnz/last-entry
        // checks: load must fail, not defer the blow-up (or silent
        // weight shift) to the first forward.
        let err = load_bytes("bad_indptr.ats", &ats2_csr_bytes(2, 2, &[0, 2, 1], &[0]))
            .unwrap_err();
        assert!(err.to_string().contains("indptr"), "{err}");
        // Duplicate column indices within a row: matmul_tb would sum
        // both entries while to_dense keeps only the last — reject.
        let err = load_bytes("dup_idx.ats", &ats2_csr_bytes(1, 4, &[0, 2], &[1, 1]))
            .unwrap_err();
        assert!(err.to_string().contains("indices"), "{err}");
        // Implausible header shape: clean error, not a huge allocation.
        let err = load_bytes(
            "huge_shape.ats",
            &ats2_csr_bytes(u32::MAX, u32::MAX, &[0], &[]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }

    /// Hand-build one ATS2 csr16 (fmt 3) entry named "w" from raw parts.
    fn ats2_csr16_bytes(rows: u32, cols: u32, indptr: &[u32], indices: &[u16]) -> Vec<u8> {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"ATS2");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.push(3u8); // fmt = csr16
        bytes.extend_from_slice(&rows.to_le_bytes());
        bytes.extend_from_slice(&cols.to_le_bytes());
        bytes.extend_from_slice(&(indices.len() as u32).to_le_bytes());
        for v in indptr {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in indices {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for _ in indices {
            bytes.extend_from_slice(&1.0f32.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn param_store_rejects_malformed_csr16() {
        // same invariants as the u32 CSR arm, on the halved-index layout
        let err = load_bytes("bad_indptr16.ats", &ats2_csr16_bytes(2, 2, &[0, 2, 1], &[0]))
            .unwrap_err();
        assert!(err.to_string().contains("indptr"), "{err}");
        let err = load_bytes("dup_idx16.ats", &ats2_csr16_bytes(1, 4, &[0, 2], &[1, 1]))
            .unwrap_err();
        assert!(err.to_string().contains("indices"), "{err}");
        // cols beyond the u16 index range must be rejected up front
        let err = load_bytes(
            "wide16.ats",
            &ats2_csr16_bytes(1, (Csr16::MAX_COLS + 1) as u32, &[0, 0], &[]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("u16 index range"), "{err}");
    }

    #[test]
    fn param_store_roundtrips_reduced_stores() {
        // rows-only, cols-only, and both-axes reduced stores survive a
        // save/load with their index maps, physical data and LOGICAL
        // accounting intact.
        let mut rng = Rng::new(6);
        let full = Mat::randn(6, 8, 1.0, &mut rng);
        let mut s = ParamStore::new();
        s.insert_store(
            "rows",
            WeightStore::DenseReduced(
                ReducedDense::from_dense(&full, Some(&[0, 3, 5]), None).unwrap(),
            ),
        );
        s.insert_store(
            "cols",
            WeightStore::DenseReduced(
                ReducedDense::from_dense(&full, None, Some(&[1, 2, 6, 7])).unwrap(),
            ),
        );
        s.insert_store(
            "both",
            WeightStore::DenseReduced(
                ReducedDense::from_dense(&full, Some(&[1, 4]), Some(&[0, 5])).unwrap(),
            ),
        );
        let dir = std::env::temp_dir().join("apt_test_param_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("reduced_roundtrip.ats");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        for name in ["rows", "cols", "both"] {
            assert_eq!(s.get(name).unwrap(), loaded.get(name).unwrap(), "{name}");
            assert_eq!(loaded.get(name).unwrap().format(), "dense_reduced");
            // logical geometry, not the physical payload shape
            assert_eq!(loaded.get(name).unwrap().n_params(), 48, "{name}");
        }
        assert_eq!(loaded.get("both").unwrap().shape(), (2, 2));
        std::fs::remove_file(path).ok();
    }

    /// Hand-build one ATS2 reduced (fmt 4) entry named "w" from raw
    /// parts; `n` in each kept pair is written verbatim so length
    /// corruption is expressible.
    fn ats2_reduced_bytes(
        full: (u32, u32),
        phys: (u32, u32),
        flags: u8,
        kept_rows: Option<(u32, &[u32])>,
        kept_cols: Option<(u32, &[u32])>,
    ) -> Vec<u8> {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"ATS2");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.push(4u8); // fmt = reduced
        bytes.extend_from_slice(&full.0.to_le_bytes());
        bytes.extend_from_slice(&full.1.to_le_bytes());
        bytes.extend_from_slice(&phys.0.to_le_bytes());
        bytes.extend_from_slice(&phys.1.to_le_bytes());
        bytes.push(flags);
        for (n, kept) in [kept_rows, kept_cols].into_iter().flatten() {
            bytes.extend_from_slice(&n.to_le_bytes());
            for v in kept {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        for _ in 0..phys.0 * phys.1 {
            bytes.extend_from_slice(&1.0f32.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn param_store_rejects_malformed_reduced() {
        // kept-row index beyond the logical row count: scatters out of
        // bounds at to_full / save time — reject at load.
        let err = load_bytes(
            "red_oob.ats",
            &ats2_reduced_bytes((4, 4), (2, 4), 1, Some((2, &[1, 9])), None),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // duplicate (non-increasing) kept indices: two physical rows
        // would claim one logical row — reject.
        let err = load_bytes(
            "red_dup.ats",
            &ats2_reduced_bytes((4, 4), (2, 4), 1, Some((2, &[2, 2])), None),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("strictly increasing"), "{err:#}");
        // kept list length disagreeing with the physical axis: fails
        // before the list allocation, with the axis named.
        let err = load_bytes(
            "red_len.ats",
            &ats2_reduced_bytes((4, 4), (2, 4), 1, Some((3, &[0, 1, 2])), None),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("kept-row list length"), "{err:#}");
        // a shrunk axis with no index map is unreconstructible
        let err = load_bytes(
            "red_nomap.ats",
            &ats2_reduced_bytes((4, 4), (2, 4), 0, None, None),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no kept-row map"), "{err:#}");
        // physical shape larger than the logical header shape
        let err = load_bytes(
            "red_grow.ats",
            &ats2_reduced_bytes((4, 4), (5, 4), 1, Some((5, &[0, 1, 2, 3, 4])), None),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("exceeds logical"), "{err:#}");
    }

    #[test]
    fn param_store_dense_accessors() {
        let mut rng = Rng::new(5);
        let mut s = ParamStore::new();
        let mut w = Mat::randn(4, 8, 1.0, &mut rng);
        crate::prune::magnitude_prune(&mut w, crate::prune::Sparsity::Unstructured { rate: 0.5 });
        s.insert_store("w", WeightStore::Csr(Csr::from_dense(&w)));
        // dense() refuses a packed layout...
        assert!(s.dense("w").is_err());
        // ...while dense_mut densifies on demand
        assert_eq!(s.dense_mut("w").unwrap(), &w);
        assert_eq!(s.get("w").unwrap().format(), "dense");
        assert!(s.dense("w").is_ok());
        assert_eq!(s.total_params(), 32);
    }
}
