//! Binary tensor store: the repo's checkpoint format ("ATS" — apt tensor
//! store). Safetensors-like: a little-endian header with named f32 tensors,
//! written/read without any external serialization crate.
//!
//! Layout:
//!   magic  b"ATS1"
//!   u32    n_entries
//!   per entry: u32 name_len | name bytes | u32 rows | u32 cols | f32 data
//! A `meta.json` sidecar (written by the model layer) carries configs.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Mat;

const MAGIC: &[u8; 4] = b"ATS1";

/// Named tensor collection (deterministic iteration order).
#[derive(Clone, Debug, Default)]
pub struct TensorStore {
    pub tensors: BTreeMap<String, Mat>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, m: Mat) {
        self.tensors.insert(name.to_string(), m);
    }

    pub fn get(&self, name: &str) -> Result<&Mat> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Mat> {
        self.tensors.get_mut(name).with_context(|| format!("tensor '{name}' missing"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|m| m.data.len()).sum()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, m) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(m.rows as u32).to_le_bytes())?;
            w.write_all(&(m.cols as u32).to_le_bytes())?;
            // bulk write the f32 payload
            let bytes: Vec<u8> = m.data.iter().flat_map(|f| f.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TensorStore> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {}", path.display());
        }
        let n = read_u32(&mut r)? as usize;
        let mut store = TensorStore::new();
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let rows = read_u32(&mut r)? as usize;
            let cols = read_u32(&mut r)? as usize;
            let mut bytes = vec![0u8; rows * cols * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            store.insert(
                std::str::from_utf8(&name).context("tensor name not utf-8")?,
                Mat::from_vec(rows, cols, data),
            );
        }
        Ok(store)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut s = TensorStore::new();
        s.insert("layer0.wq", Mat::randn(8, 8, 1.0, &mut rng));
        s.insert("layer0.wk", Mat::randn(4, 16, 0.5, &mut rng));
        s.insert("embed", Mat::randn(32, 8, 0.02, &mut rng));
        let dir = std::env::temp_dir().join("apt_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ats");
        s.save(&path).unwrap();
        let loaded = TensorStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        for name in s.names() {
            assert_eq!(s.get(name).unwrap(), loaded.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("apt_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ats");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let s = TensorStore::new();
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn total_params_counts() {
        let mut rng = Rng::new(2);
        let mut s = TensorStore::new();
        s.insert("a", Mat::randn(3, 4, 1.0, &mut rng));
        s.insert("b", Mat::randn(5, 2, 1.0, &mut rng));
        assert_eq!(s.total_params(), 22);
    }
}
