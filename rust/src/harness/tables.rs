//! Table/figure regeneration (DESIGN.md SS5 per-experiment index).
//!
//! Every public function reproduces one table or figure of the paper at
//! the scaled-down substitute workload, prints the markdown form, and
//! writes `results/<id>.{json,md}`. The *shape* of each table (method
//! orderings, degradation trends) is what must match the paper; absolute
//! perplexities are at micro-model scale.
//!
//! Set APT_FAST=1 to shrink model sizes/eval for smoke runs.

use anyhow::Result;

use crate::data::Profile;
use crate::prune::{Method, Sparsity};
use crate::runtime::Runtime;

use super::suite::{
    eval_ppl_lambada, eval_zeroshot, format_table, origin_row, prune_and_eval, save_rows, Row,
    RunOpts,
};
use super::zoo::Zoo;

fn fast() -> bool {
    std::env::var("APT_FAST").map(|v| v == "1").unwrap_or(false)
}

fn train_steps() -> usize {
    if fast() { 60 } else { 400 }
}

fn write_out(id: &str, text: &str, rows: &[Row]) -> Result<()> {
    std::fs::create_dir_all("results").ok();
    std::fs::write(format!("results/{id}.md"), text)?;
    save_rows(id, rows)?;
    Ok(())
}

/// Table 1: perplexity for transformer LLMs, 50% unstructured (SS vs SM)
/// and 2:4 (SS/SM/MS/MM), across block sizes, calibration on C4.
pub fn table1(zoo: &Zoo, runtime: Option<&Runtime>) -> Result<String> {
    let mut out = String::new();
    let mut all_rows = Vec::new();
    let settings: &[(&str, &str, Option<usize>)] = if fast() {
        &[("llama", "small", None)]
    } else {
        &[
            ("llama", "small", Some(32)),
            ("llama", "small", None),
            ("llama", "medium", None),
        ]
    };
    for &(family, size, block) in settings {
        let base = zoo.model(family, size, train_steps())?;
        let mut rows = vec![origin_row(&base, zoo)];
        // 50% unstructured: SS vs SM
        for method in [Method::SS, Method::SM] {
            let mut o = RunOpts::new(method, Sparsity::Unstructured { rate: 0.5 });
            o.block_size = block;
            rows.push(prune_and_eval(&base, zoo, &o, runtime)?);
        }
        // 2:4: SS / SM / MS / MM
        for method in [Method::SS, Method::SM, Method::MS, Method::MM] {
            let mut o = RunOpts::new(method, Sparsity::two_four());
            o.block_size = block;
            let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
            row.label = format!("{} 2:4", row.label);
            rows.push(row);
        }
        let s_label = block.map(|b| b.to_string()).unwrap_or_else(|| "all".into());
        out.push_str(&format_table(
            &format!("Table 1 — {family}-{size}, S={s_label} (calib: synth-c4)"),
            &rows,
        ));
        all_rows.extend(rows);
    }
    write_out("table1", &out, &all_rows)?;
    Ok(out)
}

/// Table 2 / A3: perplexity vs baselines at 70% / 80% sparsity.
pub fn table2(zoo: &Zoo, runtime: Option<&Runtime>) -> Result<String> {
    let mut out = String::new();
    let mut all_rows = Vec::new();
    let models: &[(&str, &str)] = if fast() {
        &[("llama", "small")]
    } else {
        &[("llama", "small"), ("opt", "small"), ("mamba", "small")]
    };
    for &(family, size) in models {
        let base = zoo.model(family, size, train_steps())?;
        let mut rows = vec![origin_row(&base, zoo)];
        for rate in [0.7, 0.8] {
            for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM] {
                let o = RunOpts::new(method, Sparsity::Unstructured { rate });
                let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
                row.label = format!("{} @{:.0}%", row.label, rate * 100.0);
                rows.push(row);
            }
        }
        out.push_str(&format_table(
            &format!("Table 2/A3 — {family}-{size}, 70%/80% sparsity (calib: synth-c4)"),
            &rows,
        ));
        all_rows.extend(rows);
    }
    write_out("table2", &out, &all_rows)?;
    Ok(out)
}

/// Table 3: Mamba models — LAMBADA perplexity + zero-shot accuracy suite,
/// calibration on the LAMBADA-like profile.
pub fn table3(zoo: &Zoo, runtime: Option<&Runtime>) -> Result<String> {
    let mut out = String::new();
    let mut all_rows = Vec::new();
    let models: &[(&str, f64)] = if fast() {
        &[("small", 0.5)]
    } else {
        &[("small", 0.5), ("small", 0.7)]
    };
    let zs_n = if fast() { 40 } else { 150 };
    for &(size, rate) in models {
        let base = zoo.model("mamba", size, train_steps())?;
        let mut rows: Vec<Row> = Vec::new();
        // original reference
        let mut orig = origin_row(&base, zoo);
        orig.ppl.insert("lambada", eval_ppl_lambada(base.as_dyn(), zoo));
        orig.zeroshot = Some(eval_zeroshot(base.as_dyn(), zoo, zs_n));
        rows.push(orig);
        for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM] {
            let mut o = RunOpts::new(method, Sparsity::Unstructured { rate });
            o.calib_profile = Profile::LambadaLike;
            o.zeroshot_n = zs_n;
            let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
            // add the LAMBADA ppl column by re-pruning? row already has c4;
            // evaluate lambada ppl on a fresh pruned copy for fidelity.
            let mut m = base.duplicate();
            let calib = zoo.calibration(Profile::LambadaLike, o.n_calib, o.calib_seq);
            let cfg = crate::coordinator::PipelineConfig::new(
                crate::prune::PruneConfig::new(method, Sparsity::Unstructured { rate }),
            );
            crate::coordinator::prune_model(m.as_dyn_mut(), &calib, &cfg, None)?;
            row.ppl.insert("lambada", eval_ppl_lambada(m.as_dyn(), zoo));
            rows.push(row);
        }
        out.push_str(&format!(
            "\n### Table 3 — mamba-{size} @{:.0}% (calib: synth-lambada)\n\n",
            rate * 100.0
        ));
        out.push_str("| method | ppl-lambada | lambada | hellaswag | piqa | arc | wino | avg |\n");
        out.push_str("|---|---|---|---|---|---|---|---|\n");
        for r in &rows {
            let z = r.zeroshot.as_ref().expect("zero-shot block");
            out.push_str(&format!(
                "| {} | {:.3} | {:.1}% | {:.1}% | {:.1}% | {:.1}% | {:.1}% | {:.2}% |\n",
                r.label,
                r.ppl.get("lambada").copied().unwrap_or(f64::NAN),
                z.lambada * 100.0,
                z.hellaswag * 100.0,
                z.piqa * 100.0,
                z.arc * 100.0,
                z.winogrande * 100.0,
                z.average() * 100.0,
            ));
        }
        all_rows.extend(rows);
    }
    write_out("table3", &out, &all_rows)?;
    Ok(out)
}

/// Tables A1/A2: the OPT-like / BLOOM-like family across block sizes.
pub fn table_family(zoo: &Zoo, family: &str, runtime: Option<&Runtime>) -> Result<String> {
    let mut out = String::new();
    let mut all_rows = Vec::new();
    let settings: &[(&str, Option<usize>)] = if fast() {
        &[("small", None)]
    } else {
        &[("small", Some(32)), ("small", None)]
    };
    for &(size, block) in settings {
        let base = zoo.model(family, size, train_steps())?;
        let mut rows = vec![origin_row(&base, zoo)];
        for method in [Method::SS, Method::SM] {
            let mut o = RunOpts::new(method, Sparsity::Unstructured { rate: 0.5 });
            o.block_size = block;
            rows.push(prune_and_eval(&base, zoo, &o, runtime)?);
        }
        for method in [Method::SS, Method::SM, Method::MS, Method::MM] {
            let mut o = RunOpts::new(method, Sparsity::two_four());
            o.block_size = block;
            let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
            row.label = format!("{} 2:4", row.label);
            rows.push(row);
        }
        let s_label = block.map(|b| b.to_string()).unwrap_or_else(|| "all".into());
        out.push_str(&format_table(
            &format!("Table {} — {family}-{size}, S={s_label}",
                     if family == "opt" { "A1" } else { "A2" }),
            &rows,
        ));
        all_rows.extend(rows);
    }
    let id = if family == "opt" { "table_a1" } else { "table_a2" };
    write_out(id, &out, &all_rows)?;
    Ok(out)
}

/// Figure A1: dampening-ratio and #calibration-samples ablations (SM).
pub fn fig_a1(zoo: &Zoo, runtime: Option<&Runtime>) -> Result<String> {
    let base = zoo.model("llama", "small", train_steps())?;
    let mut out = String::from("\n### Figure A1 — ablations (llama-small, SM @50%)\n");
    let mut all_rows = Vec::new();

    out.push_str("\n#### (a) dampening ratio gamma (n_calib=32)\n\n| gamma | wt2 | c4 |\n|---|---|---|\n");
    let gammas: &[f64] = if fast() { &[1e-2, 1e-1] } else { &[1e-4, 1e-3, 1e-2, 1e-1, 1.0] };
    for &g in gammas {
        let mut o = RunOpts::new(Method::SM, Sparsity::Unstructured { rate: 0.5 });
        o.gamma = g;
        let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
        row.label = format!("gamma={g:.0e}");
        out.push_str(&format!(
            "| {g:.0e} | {:.3} | {:.3} |\n",
            row.ppl["wt2"], row.ppl["c4"]
        ));
        all_rows.push(row);
    }

    out.push_str("\n#### (b) number of calibration samples (gamma=0.01)\n\n| n_calib | wt2 | c4 |\n|---|---|---|\n");
    let ns: &[usize] = if fast() { &[8, 32] } else { &[4, 8, 16, 32, 64, 128] };
    for &n in ns {
        let mut o = RunOpts::new(Method::SM, Sparsity::Unstructured { rate: 0.5 });
        o.n_calib = n;
        let mut row = prune_and_eval(&base, zoo, &o, runtime)?;
        row.label = format!("n_calib={n}");
        out.push_str(&format!(
            "| {n} | {:.3} | {:.3} |\n",
            row.ppl["wt2"], row.ppl["c4"]
        ));
        all_rows.push(row);
    }
    write_out("fig_a1", &out, &all_rows)?;
    Ok(out)
}

/// Dispatch by table id.
pub fn run_table(id: &str, zoo: &Zoo, runtime: Option<&Runtime>) -> Result<String> {
    match id {
        "table1" | "1" => table1(zoo, runtime),
        "table2" | "2" | "table_a3" | "a3" => table2(zoo, runtime),
        "table3" | "3" => table3(zoo, runtime),
        "table_a1" | "a1" => table_family(zoo, "opt", runtime),
        "table_a2" | "a2" => table_family(zoo, "bloom", runtime),
        "fig_a1" | "fig" => fig_a1(zoo, runtime),
        _ => anyhow::bail!("unknown table id '{id}' (table1|table2|table3|a1|a2|a3|fig_a1)"),
    }
}

pub const ALL_TABLES: [&str; 6] = ["table1", "table2", "table3", "table_a1", "table_a2", "fig_a1"];
