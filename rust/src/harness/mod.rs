//! Experiment harness: model zoo, prune+eval suite, table regeneration.
//! Shared by the CLI (`apt table ...`) and the `benches/` targets.

pub mod suite;
pub mod tables;
pub mod zoo;

pub use suite::{eval_ppl, format_table, origin_row, prune_and_eval, save_rows, Row, RunOpts};
pub use tables::{run_table, ALL_TABLES};
pub use zoo::{AnyModel, Zoo};
