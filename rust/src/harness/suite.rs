//! Experiment engine shared by the CLI and the table benches: prune a
//! fresh copy of a cached dense model with one method, evaluate perplexity
//! on the eval profiles (+ optionally zero-shot), and return a typed row.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::{prune_model, PipelineConfig};
use crate::data::{Profile, TaskGen, TaskKind};
use crate::eval::{choice_accuracy, lambada_accuracy, perplexity, ZeroShotReport};
use crate::prune::{Method, PruneConfig, Sparsity};
use crate::runtime::{Backend, Runtime};
use crate::util::Timer;

use super::zoo::{AnyModel, Zoo};

pub const EVAL_TOKENS: usize = 8_192;
pub const EVAL_SEQ: usize = 128;

/// One experiment row: method x sparsity on one model.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub method: Option<Method>,
    pub sparsity_label: String,
    pub ppl: BTreeMap<&'static str, f64>,
    pub zeroshot: Option<ZeroShotReport>,
    pub elapsed_s: f64,
}

/// Perplexity on the three eval profiles (paper's WT2/PTB/C4 columns).
pub fn eval_ppl(model: &dyn crate::model::LanguageModel, zoo: &Zoo) -> BTreeMap<&'static str, f64> {
    let mut out = BTreeMap::new();
    for (name, profile) in [
        ("wt2", Profile::Wt2Like),
        ("ptb", Profile::PtbLike),
        ("c4", Profile::C4Like),
    ] {
        let data = zoo.gen.generate(profile, EVAL_TOKENS, zoo.seed ^ 0xe7a1);
        out.insert(name, perplexity(model, &data, EVAL_SEQ));
    }
    out
}

/// Perplexity on the LAMBADA-like profile only (Table 3's PPL column).
pub fn eval_ppl_lambada(model: &dyn crate::model::LanguageModel, zoo: &Zoo) -> f64 {
    let data = zoo.gen.generate(Profile::LambadaLike, EVAL_TOKENS, zoo.seed ^ 0xe7a2);
    perplexity(model, &data, EVAL_SEQ)
}

/// The Table 3 zero-shot block.
pub fn eval_zeroshot(model: &dyn crate::model::LanguageModel, zoo: &Zoo, n: usize) -> ZeroShotReport {
    let tg = TaskGen::new(&zoo.gen);
    ZeroShotReport {
        lambada: lambada_accuracy(model, &tg.lambada_suite(n, zoo.seed ^ 10)),
        hellaswag: choice_accuracy(model, &tg.choice_suite(TaskKind::HellaSwagLike, n, zoo.seed ^ 11)),
        piqa: choice_accuracy(model, &tg.choice_suite(TaskKind::PiqaLike, n, zoo.seed ^ 12)),
        arc: choice_accuracy(model, &tg.choice_suite(TaskKind::ArcLike, n, zoo.seed ^ 13)),
        winogrande: choice_accuracy(model, &tg.choice_suite(TaskKind::WinoLike, n, zoo.seed ^ 14)),
    }
}

/// Options for one prune+eval run.
#[derive(Clone, Copy)]
pub struct RunOpts {
    pub method: Method,
    pub sparsity: Sparsity,
    pub block_size: Option<usize>,
    pub gamma: f64,
    pub n_calib: usize,
    pub calib_seq: usize,
    pub calib_profile: Profile,
    pub engine: Backend,
    pub zeroshot_n: usize, // 0 = skip
}

impl RunOpts {
    pub fn new(method: Method, sparsity: Sparsity) -> RunOpts {
        RunOpts {
            method,
            sparsity,
            block_size: None,
            gamma: 0.01,
            n_calib: 32,
            calib_seq: 64,
            calib_profile: Profile::C4Like,
            engine: Backend::Native,
            zeroshot_n: 0,
        }
    }
}

/// Prune a fresh copy of `base` and evaluate it.
pub fn prune_and_eval(
    base: &AnyModel,
    zoo: &Zoo,
    opts: &RunOpts,
    runtime: Option<&Runtime>,
) -> Result<Row> {
    let timer = Timer::start();
    let mut model = base.duplicate();
    let calib = zoo.calibration(opts.calib_profile, opts.n_calib, opts.calib_seq);
    let prune_cfg = PruneConfig::new(opts.method, opts.sparsity)
        .with_block(opts.block_size)
        .with_gamma(opts.gamma);
    let pipe_cfg = PipelineConfig::new(prune_cfg).with_engine(opts.engine);
    prune_model(model.as_dyn_mut(), &calib, &pipe_cfg, runtime)?;

    let ppl = eval_ppl(model.as_dyn(), zoo);
    let zeroshot = if opts.zeroshot_n > 0 {
        Some(eval_zeroshot(model.as_dyn(), zoo, opts.zeroshot_n))
    } else {
        None
    };
    Ok(Row {
        label: opts.method.name().to_string(),
        method: Some(opts.method),
        sparsity_label: opts.sparsity.label(),
        ppl,
        zeroshot,
        elapsed_s: timer.elapsed().as_secs_f64(),
    })
}

/// The dense-model reference row ("Origin" in the paper's tables).
pub fn origin_row(base: &AnyModel, zoo: &Zoo) -> Row {
    let timer = Timer::start();
    let ppl = eval_ppl(base.as_dyn(), zoo);
    Row {
        label: "original".into(),
        method: None,
        sparsity_label: "-".into(),
        ppl,
        zeroshot: None,
        elapsed_s: timer.elapsed().as_secs_f64(),
    }
}

/// Format rows as a GitHub-markdown table (the tables' printed form).
pub fn format_table(title: &str, rows: &[Row]) -> String {
    let mut s = format!("\n### {title}\n\n");
    let has_zs = rows.iter().any(|r| r.zeroshot.is_some());
    if has_zs {
        s.push_str("| method | sparsity | ppl(lambada-ish c4) | lambada | hellaswag | piqa | arc | wino | avg |\n");
        s.push_str("|---|---|---|---|---|---|---|---|---|\n");
        for r in rows {
            let z = r.zeroshot.clone().unwrap_or(ZeroShotReport {
                lambada: f64::NAN,
                hellaswag: f64::NAN,
                piqa: f64::NAN,
                arc: f64::NAN,
                winogrande: f64::NAN,
            });
            s.push_str(&format!(
                "| {} | {} | {:.3} | {:.1}% | {:.1}% | {:.1}% | {:.1}% | {:.1}% | {:.2}% |\n",
                r.label,
                r.sparsity_label,
                r.ppl.get("c4").copied().unwrap_or(f64::NAN),
                z.lambada * 100.0,
                z.hellaswag * 100.0,
                z.piqa * 100.0,
                z.arc * 100.0,
                z.winogrande * 100.0,
                z.average() * 100.0,
            ));
        }
    } else {
        s.push_str("| method | sparsity | wt2 | ptb | c4 | time(s) |\n|---|---|---|---|---|---|\n");
        for r in rows {
            s.push_str(&format!(
                "| {} | {} | {:.3} | {:.3} | {:.3} | {:.1} |\n",
                r.label,
                r.sparsity_label,
                r.ppl.get("wt2").copied().unwrap_or(f64::NAN),
                r.ppl.get("ptb").copied().unwrap_or(f64::NAN),
                r.ppl.get("c4").copied().unwrap_or(f64::NAN),
                r.elapsed_s,
            ));
        }
    }
    s
}

/// Dump rows as JSON into results/<name>.json.
pub fn save_rows(name: &str, rows: &[Row]) -> Result<()> {
    use crate::json::Json;
    std::fs::create_dir_all("results").ok();
    let mut arr = Vec::new();
    for r in rows {
        let mut o = Json::obj();
        o.set("label", Json::Str(r.label.clone()))
            .set("sparsity", Json::Str(r.sparsity_label.clone()))
            .set("elapsed_s", Json::Num(r.elapsed_s));
        let mut ppl = Json::obj();
        for (k, v) in &r.ppl {
            ppl.set(k, Json::Num(*v));
        }
        o.set("ppl", ppl);
        if let Some(z) = &r.zeroshot {
            let mut zo = Json::obj();
            zo.set("lambada", Json::Num(z.lambada))
                .set("hellaswag", Json::Num(z.hellaswag))
                .set("piqa", Json::Num(z.piqa))
                .set("arc", Json::Num(z.arc))
                .set("winogrande", Json::Num(z.winogrande))
                .set("average", Json::Num(z.average()));
            o.set("zeroshot", zo);
        }
        arr.push(o);
    }
    std::fs::write(
        format!("results/{name}.json"),
        Json::Arr(arr).to_string_pretty(),
    )?;
    Ok(())
}
