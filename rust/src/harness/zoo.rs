//! Model zoo: builds, trains and disk-caches the stand-in model families.
//!
//! Families (DESIGN.md SS2 substitution table):
//!   - "llama"  — microllama, SwiGLU ff=2d          (stands in for LLaMA2)
//!   - "opt"    — microllama geometry with ff=4d    (stands in for OPT)
//!   - "bloom"  — ff=4d, fewer/wider heads          (stands in for BLOOM)
//!   - "mamba"  — micromamba                        (stands in for Mamba)
//!
//! Checkpoints are cached under `results/model_cache/` keyed by
//! (family, size, steps, seed) so every table reuses the same dense model.

use std::path::PathBuf;

use anyhow::Result;

use crate::data::{CorpusGen, Profile};
use crate::model::{
    train, DecodeSession, LanguageModel, Mamba, MambaConfig, TrainConfig, Transformer,
    TransformerConfig,
};
use crate::serve::{Engine, EngineConfig};
use crate::util::Rng;

/// Concrete model wrapper so table code can clone fresh copies per method.
pub enum AnyModel {
    Llama(Transformer),
    Mamba(Mamba),
}

impl AnyModel {
    pub fn as_dyn(&self) -> &dyn LanguageModel {
        match self {
            AnyModel::Llama(m) => m,
            AnyModel::Mamba(m) => m,
        }
    }

    pub fn as_dyn_mut(&mut self) -> &mut dyn LanguageModel {
        match self {
            AnyModel::Llama(m) => m,
            AnyModel::Mamba(m) => m,
        }
    }

    pub fn duplicate(&self) -> AnyModel {
        match self {
            AnyModel::Llama(m) => AnyModel::Llama(Transformer {
                cfg: m.cfg,
                params: m.params.clone(),
            }),
            AnyModel::Mamba(m) => AnyModel::Mamba(Mamba { cfg: m.cfg, params: m.params.clone() }),
        }
    }

    /// Start an incremental-decode session over this model (the
    /// single-stream serving path: prefill once, then O(T·L) /
    /// O(1)-per-token steps).
    pub fn decode_session(&self) -> DecodeSession<'_, dyn LanguageModel + '_> {
        DecodeSession::new(self.as_dyn())
    }

    /// Start a batched continuous-decoding engine over this model (the
    /// multi-stream serving path: one (B, d) matmul per linear across
    /// all active streams; see [`crate::serve`]).
    pub fn engine(&self, cfg: EngineConfig) -> Engine<'_> {
        Engine::new(self.as_dyn(), cfg)
    }

    /// Speculative serving over this (dense target) model with a pruned
    /// `draft` — typically a [`AnyModel::duplicate`] run through
    /// [`crate::coordinator::prune_draft_model`]. Greedy streams decode
    /// in draft-propose / target-verify rounds (see
    /// [`crate::serve::speculative`]); output is bit-identical to
    /// [`AnyModel::engine`] on the same requests.
    pub fn spec_engine<'a>(
        &'a self,
        draft: &'a AnyModel,
        k: usize,
        cfg: EngineConfig,
    ) -> Engine<'a> {
        Engine::speculative(self.as_dyn(), draft.as_dyn(), k, cfg)
    }
}

pub struct Zoo {
    pub gen: CorpusGen,
    pub cache_dir: PathBuf,
    pub seed: u64,
    pub train_tokens: usize,
}

impl Zoo {
    pub fn new(seed: u64) -> Zoo {
        let cache_dir = PathBuf::from("results/model_cache");
        std::fs::create_dir_all(&cache_dir).ok();
        Zoo { gen: CorpusGen::default_setup(seed), cache_dir, seed, train_tokens: 120_000 }
    }

    pub fn vocab(&self) -> usize {
        self.gen.tokenizer.vocab_size()
    }

    pub fn transformer_config(&self, family: &str, size: &str) -> TransformerConfig {
        let v = self.vocab();
        match (family, size) {
            ("llama", "small") => TransformerConfig { vocab: v, d_model: 128, n_layers: 4, n_heads: 4, d_ff: 256, max_seq: 256 },
            ("llama", "medium") => TransformerConfig { vocab: v, d_model: 256, n_layers: 6, n_heads: 8, d_ff: 512, max_seq: 256 },
            ("llama", "large") => TransformerConfig { vocab: v, d_model: 384, n_layers: 8, n_heads: 8, d_ff: 768, max_seq: 256 },
            ("opt", "small") => TransformerConfig { vocab: v, d_model: 96, n_layers: 4, n_heads: 4, d_ff: 384, max_seq: 256 },
            ("opt", "medium") => TransformerConfig { vocab: v, d_model: 192, n_layers: 6, n_heads: 6, d_ff: 768, max_seq: 256 },
            ("bloom", "small") => TransformerConfig { vocab: v, d_model: 112, n_layers: 4, n_heads: 2, d_ff: 448, max_seq: 256 },
            ("bloom", "medium") => TransformerConfig { vocab: v, d_model: 224, n_layers: 5, n_heads: 4, d_ff: 896, max_seq: 256 },
            _ => panic!("unknown transformer family/size {family}/{size}"),
        }
    }

    pub fn mamba_config(&self, size: &str) -> MambaConfig {
        let v = self.vocab();
        match size {
            "small" => MambaConfig { vocab: v, d_model: 128, d_inner: 256, n_layers: 4, max_seq: 256 },
            "medium" => MambaConfig { vocab: v, d_model: 192, d_inner: 384, n_layers: 6, max_seq: 256 },
            _ => panic!("unknown mamba size {size}"),
        }
    }

    fn cache_path(&self, family: &str, size: &str, steps: usize) -> PathBuf {
        self.cache_dir.join(format!("{family}_{size}_s{steps}_seed{}.ats", self.seed))
    }

    /// Build-or-load a trained dense model.
    pub fn model(&self, family: &str, size: &str, steps: usize) -> Result<AnyModel> {
        let path = self.cache_path(family, size, steps);
        let train_cfg = TrainConfig {
            steps,
            batch: 8,
            seq_len: 64,
            log_every: (steps / 6).max(1),
            seed: self.seed ^ 0xbeef,
            ..Default::default()
        };
        if family == "mamba" {
            let cfg = self.mamba_config(size);
            if path.exists() {
                return Ok(AnyModel::Mamba(Mamba::load(cfg, &path)?));
            }
            let mut m = Mamba::init(cfg, &mut Rng::new(self.seed));
            let data = self.gen.generate(Profile::C4Like, self.train_tokens, self.seed ^ 1);
            train(&mut m, &data, &train_cfg);
            m.save(&path)?;
            Ok(AnyModel::Mamba(m))
        } else {
            let cfg = self.transformer_config(family, size);
            if path.exists() {
                return Ok(AnyModel::Llama(Transformer::load(cfg, &path)?));
            }
            let mut m = Transformer::init(cfg, &mut Rng::new(self.seed));
            let data = self.gen.generate(Profile::C4Like, self.train_tokens, self.seed ^ 1);
            train(&mut m, &data, &train_cfg);
            m.save(&path)?;
            Ok(AnyModel::Llama(m))
        }
    }

    /// Calibration sequences for a profile (the paper: random segments).
    pub fn calibration(&self, profile: Profile, n: usize, seq_len: usize) -> Vec<Vec<u32>> {
        let data = self.gen.generate(profile, (n * seq_len * 3).max(20_000), self.seed ^ 2);
        let mut rng = Rng::new(self.seed ^ 3);
        data.sample_calibration(n, seq_len, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_configs_distinct() {
        let zoo = Zoo::new(1);
        let llama = zoo.transformer_config("llama", "small");
        let opt = zoo.transformer_config("opt", "small");
        let bloom = zoo.transformer_config("bloom", "small");
        assert!(opt.d_ff == 4 * opt.d_model);
        assert!(llama.d_ff == 2 * llama.d_model);
        assert_ne!(opt.d_model, bloom.d_model);
    }

    #[test]
    fn model_cache_roundtrip() {
        let mut zoo = Zoo::new(99);
        zoo.cache_dir = std::env::temp_dir().join("apt_zoo_test");
        std::fs::create_dir_all(&zoo.cache_dir).unwrap();
        zoo.train_tokens = 8_000;
        let m1 = zoo.model("llama", "small", 5).unwrap();
        let path = zoo.cache_path("llama", "small", 5);
        assert!(path.exists());
        let m2 = zoo.model("llama", "small", 5).unwrap(); // from cache
        let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
        assert_eq!(
            m1.as_dyn().forward_loss(&toks, (1, 32)),
            m2.as_dyn().forward_loss(&toks, (1, 32))
        );
        std::fs::remove_dir_all(&zoo.cache_dir).ok();
    }

    #[test]
    fn decode_session_matches_full_forward_on_zoo_model() {
        let mut zoo = Zoo::new(102);
        zoo.cache_dir = std::env::temp_dir().join("apt_zoo_test3");
        std::fs::create_dir_all(&zoo.cache_dir).unwrap();
        zoo.train_tokens = 8_000;
        let m = zoo.model("llama", "small", 2).unwrap();
        let toks: Vec<u32> = (0..24).map(|i| (i * 3 % 50) as u32).collect();
        let mut s = m.decode_session();
        s.prefill(&toks);
        assert_eq!(s.len(), toks.len());
        assert_eq!(s.argmax_last(), m.as_dyn().predict_last_full(&toks));
        // the batched engine agrees with the single-stream session
        let mut eng = m.engine(EngineConfig::default());
        eng.submit(crate::serve::Request::greedy(toks.clone(), 4));
        eng.run();
        let done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, s.generate(4));
        std::fs::remove_dir_all(&zoo.cache_dir).ok();
    }

    #[test]
    fn duplicate_is_independent() {
        let mut zoo = Zoo::new(100);
        zoo.cache_dir = std::env::temp_dir().join("apt_zoo_test2");
        std::fs::create_dir_all(&zoo.cache_dir).unwrap();
        zoo.train_tokens = 8_000;
        let base = zoo.model("mamba", "small", 2).unwrap();
        let mut copy = base.duplicate();
        copy.as_dyn_mut().block_weight_mut(0, "in_proj").dense_mut().data[0] += 1.0;
        assert_ne!(
            base.as_dyn().block_weight(0, "in_proj").as_dense().unwrap().data[0],
            copy.as_dyn().block_weight(0, "in_proj").as_dense().unwrap().data[0]
        );
        std::fs::remove_dir_all(&zoo.cache_dir).ok();
    }
}
