//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` + manifest)
//! produced by `python/compile/aot.py` and executes them on the CPU PJRT
//! client. This is the only place the L3 coordinator touches XLA; Python
//! never runs at request time.
//!
//! Executables are compiled lazily and memoized per artifact file. Shapes
//! not covered by the manifest fall back to the native Rust solvers (the
//! coordinator decides; see `Backend`).
//!
//! The `xla` crate is unavailable in the offline build, so everything
//! touching PJRT is gated behind the `pjrt` cargo feature. Without it,
//! [`Runtime::load`] returns an error and every caller falls back to the
//! native solvers (manifest parsing still works, so configs stay
//! checkable offline).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
use anyhow::{bail, Result};

use crate::json::{self, Json};
use crate::tensor::Mat;

/// Which implementation the coordinator uses for the pruning math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust solvers (any shape).
    Native,
    /// AOT HLO executables via PJRT where a matching artifact exists,
    /// native fallback otherwise.
    Hlo,
}

impl Backend {
    pub fn from_name(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(Backend::Native),
            "hlo" | "pjrt" | "xla" => Some(Backend::Hlo),
            _ => None,
        }
    }
}

/// One manifest entry (mirrors aot.py's shape_sig output).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub n: usize,
    pub m: usize,
    pub t: usize,
    pub k: usize,
}

/// Parse `manifest.json` into artifact entries (feature-independent).
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactEntry>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        anyhow::anyhow!("read {} (run `make artifacts`): {e}", manifest_path.display())
    })?;
    let root = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
    if root.get("format").and_then(Json::as_str) != Some("hlo-text-v1") {
        bail!("unsupported manifest format");
    }
    let mut entries = Vec::new();
    for e in root.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
        entries.push(ArtifactEntry {
            name: e.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
            file: e.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
            n: e.get("n").and_then(Json::as_usize).unwrap_or(0),
            m: e.get("m").and_then(Json::as_usize).unwrap_or(0),
            t: e.get("t").and_then(Json::as_usize).unwrap_or(0),
            k: e.get("k").and_then(Json::as_usize).unwrap_or(0),
        });
    }
    Ok(entries)
}

#[cfg(feature = "pjrt")]
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    entries: Vec<ArtifactEntry>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

/// Feature-off stub: construction always fails, so the methods below are
/// unreachable at runtime but keep every call site compiling.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    entries: Vec<ArtifactEntry>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: executing artifacts needs the `pjrt` feature (and the
    /// external `xla` crate). The manifest is still validated first so a
    /// broken manifest is reported over a missing feature.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let entries = parse_manifest(dir)?;
        let _ = entries;
        bail!("built without the `pjrt` feature: HLO engine unavailable (native solvers still run)")
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Find the artifact for a graph name + layer shape.
    pub fn find(&self, name: &str, n: usize, m: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name && e.n == n && e.m == m)
    }

    /// Find by name + input-width only (hessian graphs ignore n).
    pub fn find_m(&self, name: &str, m: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name && e.m == m)
    }

    pub fn exec(
        &self,
        _entry: &ArtifactEntry,
        _mats: &[&Mat],
        _scalars: &[f32],
        _out_rows: &[usize],
    ) -> Result<Vec<Mat>> {
        bail!("built without the `pjrt` feature")
    }

    pub fn exec_prune(&self, _entry: &ArtifactEntry, _w: &Mat, _hinv: &Mat) -> Result<(Mat, f64)> {
        bail!("built without the `pjrt` feature")
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load the manifest and connect the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let entries = parse_manifest(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { dir: dir.to_path_buf(), client, entries, cache: Mutex::new(HashMap::new()) })
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Find the artifact for a graph name + layer shape.
    pub fn find(&self, name: &str, n: usize, m: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name && e.n == n && e.m == m)
    }

    /// Find by name + input-width only (hessian graphs ignore n).
    pub fn find_m(&self, name: &str, m: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name && e.m == m)
    }

    fn executable(
        &self,
        entry: &ArtifactEntry,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&entry.file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse hlo {}: {e:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", entry.file))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 matrix inputs (+ optional trailing f32
    /// scalars), returning the tuple outputs as matrices with the given
    /// row counts (cols inferred).
    pub fn exec(
        &self,
        entry: &ArtifactEntry,
        mats: &[&Mat],
        scalars: &[f32],
        out_rows: &[usize],
    ) -> Result<Vec<Mat>> {
        let exe = self.executable(entry)?;
        let mut inputs = Vec::with_capacity(mats.len() + scalars.len());
        for m in mats {
            let lit = xla::Literal::vec1(&m.data)
                .reshape(&[m.rows as i64, m.cols as i64])
                .map_err(|e| anyhow!("reshape literal: {e:?}"))?;
            inputs.push(lit);
        }
        for &s in scalars {
            inputs.push(xla::Literal::scalar(s));
        }
        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", entry.file))?;
        let first = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .context("no output buffer")?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let data: Vec<f32> = p.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            let rows = out_rows.get(i).copied().unwrap_or(1).max(1);
            let cols = if data.is_empty() { 0 } else { (data.len() / rows).max(1) };
            out.push(Mat::from_vec(rows.min(data.len().max(1)), cols, data));
        }
        Ok(out)
    }

    /// Convenience: run a `prune_*` artifact on (w, hinv) -> (pruned w,
    /// Eq. 12 predicted loss where the graph emits one).
    pub fn exec_prune(&self, entry: &ArtifactEntry, w: &Mat, hinv: &Mat) -> Result<(Mat, f64)> {
        let outs = self.exec(entry, &[w, hinv], &[], &[w.rows, 1])?;
        let w_new = outs.first().context("missing w output")?.clone();
        if w_new.shape() != w.shape() {
            bail!("artifact returned shape {:?}, want {:?}", w_new.shape(), w.shape());
        }
        let loss = outs.get(1).and_then(|m| m.data.first()).copied().unwrap_or(f32::NAN);
        Ok((w_new, loss as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        if cfg!(not(feature = "pjrt")) {
            eprintln!("pjrt feature off; runtime tests skipped");
            return None;
        }
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(&dir).expect("runtime load"))
        } else {
            eprintln!("artifacts missing; run `make artifacts` (test skipped)");
            None
        }
    }

    #[test]
    fn manifest_loads_and_lists_entries() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.entries().is_empty());
        assert!(rt.find("prune_24_sm", 64, 64).is_some());
        assert!(rt.find("prune_24_sm", 63, 63).is_none());
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn hessian_update_roundtrip_matches_native() {
        let Some(rt) = runtime() else { return };
        let entry = rt.find_m("hessian_update", 64).expect("artifact").clone();
        let mut rng = crate::util::Rng::new(1);
        let x = Mat::randn(entry.t, 64, 1.0, &mut rng);
        let h0 = Mat::zeros(64, 64);
        let outs = rt.exec(&entry, &[&x, &h0], &[], &[64]).unwrap();
        let h = &outs[0];
        let mut acc = crate::prune::HessianAccumulator::new(64);
        acc.add_chunk(&x);
        let native = acc.h.to_f32();
        assert!(h.max_abs_diff(&native) < 1e-1, "{}", h.max_abs_diff(&native));
    }

    #[test]
    fn prune_sm_artifact_produces_sparse_rows() {
        let Some(rt) = runtime() else { return };
        let entry = rt.find("prune_sm", 64, 64).expect("artifact").clone();
        let mut rng = crate::util::Rng::new(2);
        let w = Mat::randn(64, 64, 1.0, &mut rng);
        let x = Mat::randn(256, 64, 1.0, &mut rng);
        let mut acc = crate::prune::HessianAccumulator::new(64);
        acc.add_chunk(&x);
        let (_hd, hinv) = acc.finalize(0.01);
        let hinv32 = hinv.to_f32();
        let (w_new, loss) = rt.exec_prune(&entry, &w, &hinv32).unwrap();
        for r in 0..64 {
            let zeros = w_new.row(r).iter().filter(|&&v| v == 0.0).count();
            assert!(zeros >= 32, "row {r}: {zeros}");
        }
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn prune_24_artifacts_match_native_structure() {
        let Some(rt) = runtime() else { return };
        for name in ["prune_24_sm", "prune_24_mm", "prune_24_ms"] {
            let entry = rt.find(name, 64, 64).expect("artifact").clone();
            let mut rng = crate::util::Rng::new(3);
            let w = Mat::randn(64, 64, 1.0, &mut rng);
            let x = Mat::randn(256, 64, 1.0, &mut rng);
            let mut acc = crate::prune::HessianAccumulator::new(64);
            acc.add_chunk(&x);
            let (_hd, hinv) = acc.finalize(0.01);
            let (w_new, _) = rt.exec_prune(&entry, &w, &hinv.to_f32()).unwrap();
            for r in 0..64 {
                for g in 0..16 {
                    let zeros =
                        (0..4).filter(|&i| w_new[(r, g * 4 + i)] == 0.0).count();
                    assert!(zeros >= 2, "{name} row {r} group {g}");
                }
            }
        }
    }
}
