//! Structured pruning: heads and FFN channels out, smaller dense matmuls in.
//!
//! Trains a tiny dense transformer, scores its attention heads and FFN
//! channels on the calibration Hessians, drops half of each under the
//! least-squares reconstruction (`coordinator::structured_prune_transformer`),
//! and leaves every block linear as a physically smaller dense matmul
//! (`WeightStore::DenseReduced`). The reduced model is gated against the
//! masked full-shape oracle (same decisions, exact zeros in the dropped
//! columns) to <1e-5 at the logits, then served through the batched
//! engine, evaluated for perplexity, and used as a speculative draft for
//! its own dense source — all straight off the reduced layouts.
//!
//!     cargo run --release --example structured_prune

use apt::coordinator::structured_prune_transformer;
use apt::data::{CorpusGen, Profile};
use apt::eval::perplexity;
use apt::model::{train, DecodeSession, LanguageModel, TrainConfig, Transformer, TransformerConfig};
use apt::prune::StructuredConfig;
use apt::serve::speculative::spec_serve_report;
use apt::serve::{Engine, EngineConfig, Request};
use apt::util::Rng;

fn main() {
    let gen = CorpusGen::new(60, 2, 7);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let mut dense = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 96, max_seq: 256 },
        &mut Rng::new(3),
    );
    train(
        &mut dense,
        &data,
        &TrainConfig { steps: 60, batch: 8, seq_len: 32, log_every: 1000, ..Default::default() },
    );
    let calib = data.sample_calibration(8, 32, &mut Rng::new(9));

    // reduced run + masked full-shape oracle from the same calibration set
    let cfg = StructuredConfig::new(0.5);
    let mut reduced = Transformer { cfg: dense.cfg, params: dense.params.clone() };
    let rep = structured_prune_transformer(&mut reduced, &calib, &cfg).unwrap();
    let mut masked = Transformer { cfg: dense.cfg, params: dense.params.clone() };
    structured_prune_transformer(&mut masked, &calib, &StructuredConfig { masked: true, ..cfg })
        .unwrap();

    for b in &rep.blocks {
        let (kh, nh) = b.kept_heads.expect("transformer blocks report heads");
        let (kf, nf) = b.kept_ffn.expect("transformer blocks report ffn channels");
        println!("block {}: kept {kh}/{nh} heads, {kf}/{nf} ffn channels", b.block);
    }
    println!(
        "achieved FLOPs ratio {:.3} ({} linears now dense_reduced)",
        rep.flops_ratio(),
        rep.linears.iter().filter(|l| l.format == "dense_reduced").count()
    );
    assert!((rep.flops_ratio() - 0.5).abs() < 0.05);
    let wq = reduced.weight(0, "wq");
    println!(
        "block 0 wq: physical {:?} of logical {} params",
        wq.shape(),
        wq.n_params()
    );

    // oracle gate: reduced logits match the masked full-shape forward
    let probe: Vec<u32> = (0..32).map(|i| ((i * 3 + 11) % vocab) as u32).collect();
    let a = reduced.next_token_logprobs(&probe, (1, probe.len()));
    let b = masked.next_token_logprobs(&probe, (1, probe.len()));
    let mut max_d = 0.0f64;
    for (x, y) in a.iter().zip(&b) {
        max_d = max_d.max((x - y).abs());
    }
    assert!(max_d < 1e-5, "reduced vs masked oracle: {max_d}");
    println!("reduced vs masked-oracle logprobs: max |d| = {max_d:.2e}");

    // the reduced model serves unchanged: batched engine vs solo sessions
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..8 + 4 * i).map(|j| ((j * 3 + i * 11) % vocab) as u32).collect())
        .collect();
    let mut eng = Engine::new(&reduced, EngineConfig { max_batch: 4, ..Default::default() });
    for p in &prompts {
        eng.submit(Request::greedy(p.clone(), 12));
    }
    eng.run();
    let mut done = eng.take_finished();
    done.sort_by_key(|c| c.id);
    for (i, p) in prompts.iter().enumerate() {
        let mut s = DecodeSession::new(&reduced);
        s.prefill(p);
        assert_eq!(done[i].tokens, s.generate(12), "engine stream {i}");
    }
    println!("engine over reduced stores: {} streams match solo sessions", prompts.len());

    // eval runs straight off the reduced layouts
    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 5);
    let ppl_dense = perplexity(&dense, &eval_data, 64);
    let ppl_reduced = perplexity(&reduced, &eval_data, 64);
    println!("perplexity: dense {ppl_dense:.2} -> structured {ppl_reduced:.2}");
    assert!(ppl_reduced.is_finite());

    // and the reduced model drafts for its own dense source, losslessly
    let r = spec_serve_report(
        &dense,
        &reduced,
        &prompts,
        12,
        4,
        EngineConfig { max_batch: 4, ..Default::default() },
    );
    println!(
        "speculative (structured draft, k=4): acceptance {:.3}, {:.2} tokens/round",
        r.acceptance_rate, r.tokens_per_round
    );
    println!("structured_prune: OK");
}
