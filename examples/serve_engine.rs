//! The serving engine: batched continuous decoding over mixed requests.
//!
//! Submits a handful of concurrent requests — greedy, temperature and
//! top-k sampled — to one `Engine`, which steps ALL active streams
//! through a single (B, d) matmul per linear (amortizing every sparse
//! weight read across the batch), refills slots from the queue as
//! streams finish, and bounds each stream's K/V with a sliding window.
//!
//!     cargo run --release --example serve_engine

use apt::data::{CorpusGen, Profile};
use apt::model::{train, DecodeSession, TrainConfig, Transformer, TransformerConfig};
use apt::serve::{Engine, EngineConfig, Request, SamplingParams};
use apt::util::{Rng, Timer};

fn main() {
    let gen = CorpusGen::new(60, 2, 7);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let mut model = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 256 },
        &mut Rng::new(3),
    );
    train(
        &mut model,
        &data,
        &TrainConfig { steps: 60, batch: 8, seq_len: 32, log_every: 1000, ..Default::default() },
    );

    let prompt = |salt: usize, len: usize| -> Vec<u32> {
        (0..len).map(|i| ((i * 3 + salt * 11) % vocab) as u32).collect()
    };

    // 6 requests through 4 slots: the engine admits the first four
    // (prefilled as ONE padded batch), then continuously refills as
    // streams finish. Tokens stream through the on_token hook the
    // moment they are sampled, not only at completion.
    let streamed: std::rc::Rc<std::cell::RefCell<std::collections::BTreeMap<_, Vec<u32>>>> =
        Default::default();
    let sink = streamed.clone();
    let mut eng = Engine::new(&model, EngineConfig { max_batch: 4, max_seq: Some(128), ..Default::default() });
    eng.set_on_token(move |id, tok| sink.borrow_mut().entry(id).or_default().push(tok));
    let mut ids = Vec::new();
    ids.push(eng.submit(Request::greedy(prompt(0, 48), 16)));
    ids.push(eng.submit(Request::greedy(prompt(1, 32), 16)));
    ids.push(eng.submit(Request {
        prompt: prompt(2, 40),
        max_new_tokens: 16,
        sampling: SamplingParams::temperature(0.8, 42),
    }));
    ids.push(eng.submit(Request {
        prompt: prompt(3, 24),
        max_new_tokens: 16,
        sampling: SamplingParams::top_k(8, 0.9, 7),
    }));
    ids.push(eng.submit(Request::greedy(prompt(4, 36), 16)));
    ids.push(eng.submit(Request {
        prompt: prompt(5, 28),
        max_new_tokens: 16,
        sampling: SamplingParams::temperature(1.2, 99),
    }));
    println!("submitted {} requests (max_batch = 4, window = 128)", ids.len());

    let t = Timer::start();
    let total = eng.run();
    let batched_ms = t.elapsed_ms();
    let mut done = eng.take_finished();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), ids.len());
    for c in &done {
        println!(
            "  request {:?} (+{} prompt tokens, finish {:?}): {:?}",
            c.id,
            c.prompt.len(),
            c.finish,
            c.tokens
        );
        assert_eq!(c.finish, apt::serve::FinishReason::Length, "happy path only here");
        // the streamed view saw exactly the completed tokens, in order
        assert_eq!(
            streamed.borrow().get(&c.id),
            Some(&c.tokens),
            "on_token stream must match the completion"
        );
    }

    // the greedy streams must agree with independent single-stream
    // sessions — batch composition never changes a stream's tokens
    let t = Timer::start();
    for &(salt, len) in &[(0usize, 48usize), (1, 32), (4, 36)] {
        let mut s = DecodeSession::new(&model);
        s.prefill(&prompt(salt, len));
        let solo = s.generate(16);
        let c = done.iter().find(|c| c.prompt == prompt(salt, len)).unwrap();
        assert_eq!(c.tokens, solo, "batched and solo greedy decode must agree");
    }
    let solo_ms = t.elapsed_ms();

    println!(
        "\n{total} tokens in {batched_ms:.1} ms batched \
         ({:.0} tok/s); 3 equivalent solo greedy streams took {solo_ms:.1} ms",
        total as f64 / (batched_ms / 1000.0)
    );
    let st = eng.stats();
    println!(
        "engine stats: {} completed, {} preemptions, {} deadline, {} cancelled, \
         {} quarantined, kv pages peak {}",
        st.completed,
        st.preemptions,
        st.deadline_expired,
        st.cancelled,
        st.quarantined,
        st.kv_pages_peak
    );
    println!("serve_engine: OK");
}
