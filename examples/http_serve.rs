//! HTTP serving smoke: start the std-only server on an ephemeral
//! loopback port, exercise every endpoint once (plain generate,
//! streamed generate, /metrics, /healthz), and shut down gracefully.
//!
//!     cargo run --release --example http_serve

use apt::data::{CorpusGen, Profile};
use apt::model::{train, TrainConfig, Transformer, TransformerConfig};
use apt::server::{client, Server, ServerConfig};
use apt::util::Rng;

fn main() {
    let gen = CorpusGen::new(60, 2, 7);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let mut model = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 256 },
        &mut Rng::new(3),
    );
    train(
        &mut model,
        &data,
        &TrainConfig { steps: 60, batch: 8, seq_len: 32, log_every: 1000, ..Default::default() },
    );

    let h = Server::start(model, "127.0.0.1:0", ServerConfig::default()).expect("bind loopback");
    let addr = h.addr();
    println!("serving on http://{addr}");

    let r = client::request(addr, "GET", "/healthz", None).expect("healthz");
    assert_eq!(r.status, 200);
    println!("GET /healthz -> {} {:?}", r.status, String::from_utf8_lossy(&r.body).trim());

    let prompt: Vec<String> = (0..8).map(|i| ((i * 3 + 5) % vocab).to_string()).collect();
    let body = format!(
        r#"{{"prompt": [{}], "max_new_tokens": 12, "temperature": 0.8, "seed": 7}}"#,
        prompt.join(",")
    );
    let r = client::request(addr, "POST", "/v1/generate", Some(&body)).expect("generate");
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json().expect("json body");
    println!(
        "POST /v1/generate -> {} finish={} tokens={}",
        r.status,
        v.get("finish").unwrap().as_str().unwrap(),
        v.get("tokens").unwrap().as_arr().unwrap().len(),
    );

    let sbody = format!(
        r#"{{"prompt": [{}], "max_new_tokens": 12, "stream": true}}"#,
        prompt.join(",")
    );
    let (status, chunks) = client::stream_request(addr, "/v1/generate", &sbody).expect("stream");
    assert_eq!(status, 200);
    let (toks, terminal) = client::split_stream(&chunks);
    let terminal = terminal.expect("terminal chunk");
    println!(
        "POST /v1/generate (stream) -> {} chunks, {} tokens, finish={}",
        chunks.len(),
        toks.len(),
        terminal.get("finish").unwrap().as_str().unwrap(),
    );
    assert_eq!(toks.len(), 12);

    // keep-alive: several requests down ONE reused connection
    let mut kc = client::Client::new(addr);
    for _ in 0..3 {
        let r = kc.request("POST", "/v1/generate", Some(&body)).expect("keep-alive generate");
        assert_eq!(r.status, 200);
    }
    assert_eq!(kc.connects_made(), 1, "three requests must reuse one connection");
    println!("keep-alive client: 3 requests, {} TCP connect(s)", kc.connects_made());
    drop(kc);

    let m = client::request(addr, "GET", "/metrics", None).expect("metrics");
    assert_eq!(m.status, 200);
    let text = String::from_utf8_lossy(&m.body).into_owned();
    println!("GET /metrics ->");
    for k in [
        "apt_engine_completions_total",
        "apt_engine_tokens_generated_total",
        "apt_engine_kv_pages_live",
        "apt_http_requests_total",
        "apt_http_keepalive_reuses_total",
    ] {
        println!("  {k} {}", client::metric(&text, k).expect(k));
    }
    assert_eq!(client::metric(&text, "apt_engine_completions_total"), Some(5));
    assert_eq!(client::metric(&text, "apt_engine_kv_pages_live"), Some(0));
    assert_eq!(client::metric(&text, "apt_http_keepalive_reuses_total"), Some(2));

    let report = h.shutdown();
    println!(
        "shutdown drained ({} pool workers joined); http_serve smoke passed",
        report.pool_workers_joined
    );
}
