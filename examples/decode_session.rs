//! Incremental decode sessions: prefill a context once, then generate
//! token-by-token from per-block cached state — O(T·L) per token for the
//! transformer's K/V caches, O(1) per token for mamba's recurrent state,
//! vs the O(T²·L) full re-forward the serving path used to pay.
//!
//!     cargo run --release --example decode_session

use apt::data::{CorpusGen, Profile};
use apt::model::{
    train, DecodeSession, LanguageModel, Mamba, MambaConfig, TrainConfig, Transformer,
    TransformerConfig,
};
use apt::util::{Rng, Timer};

fn demo(name: &str, model: &dyn LanguageModel, prompt: &[u32]) {
    // the session path: one prefill, then greedy steps from cached state
    let t = Timer::start();
    let mut session = DecodeSession::new(model);
    session.prefill(prompt);
    let generated = session.generate(16);
    let incremental_ms = t.elapsed_ms();

    // the old path: re-run the full growing context for every token
    let t = Timer::start();
    let mut ctx = prompt.to_vec();
    let mut full = Vec::new();
    for _ in 0..16 {
        let tok = model.predict_last_full(&ctx);
        full.push(tok);
        ctx.push(tok);
    }
    let full_ms = t.elapsed_ms();

    // Exact equality is intentional: within one binary both paths run the
    // same per-element FMA kernels in the same order (see PERF.md
    // iteration 5), so the greedy rollouts are bit-identical.
    assert_eq!(generated, full, "incremental and full decode must agree");
    println!("{name}: generated {generated:?}");
    println!(
        "  16 tokens after a {}-token prompt: full {:.1} ms, session {:.1} ms ({:.1}x)",
        prompt.len(),
        full_ms,
        incremental_ms,
        full_ms / incremental_ms.max(1e-9)
    );
}

fn main() {
    let gen = CorpusGen::new(60, 2, 7);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let prompt: Vec<u32> = (0..96).map(|i| (i * 3 % 50) as u32).collect();
    let tcfg = TrainConfig {
        steps: 60,
        batch: 8,
        seq_len: 32,
        log_every: 1000,
        ..Default::default()
    };

    let mut llama = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 256 },
        &mut Rng::new(3),
    );
    train(&mut llama, &data, &tcfg);
    demo("microllama", &llama, &prompt);

    let mut mamba = Mamba::init(
        MambaConfig { vocab, d_model: 64, d_inner: 128, n_layers: 2, max_seq: 256 },
        &mut Rng::new(4),
    );
    train(&mut mamba, &data, &tcfg);
    demo("micromamba", &mamba, &prompt);
}
