//! Sparsity sweep (Table 2/A3 scenario): how gracefully does each method
//! degrade as sparsity rises 50% -> 80%? Prints one series per method —
//! the crossover/collapse shape is the paper's headline robustness claim.
//! Also demonstrates packing the pruned model into the sparse formats.
//!
//!     cargo run --release --example sparsity_sweep

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::Profile;
use apt::eval::perplexity;
use apt::harness::Zoo;
use apt::model::Transformer;
use apt::prune::{Method, PruneConfig, Sparsity};

fn main() -> anyhow::Result<()> {
    let zoo = Zoo::new(42);
    let base = zoo.model("llama", "small", 400)?;
    let apt::harness::AnyModel::Llama(base) = base else { unreachable!() };
    let calib = zoo.calibration(Profile::C4Like, 32, 64);
    let eval_data = zoo.gen.generate(Profile::Wt2Like, 8_192, 5);

    let rates = [0.5, 0.6, 0.7, 0.8];
    println!("wt2 perplexity by sparsity (microllama-small)\n");
    print!("{:<16}", "method");
    for r in rates {
        print!("{:>10}", format!("{:.0}%", r * 100.0));
    }
    println!();

    for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM] {
        print!("{:<16}", method.name());
        for rate in rates {
            let mut pruned = Transformer { cfg: base.cfg, params: base.params.clone() };
            let cfg =
                PipelineConfig::new(PruneConfig::new(method, Sparsity::Unstructured { rate }));
            prune_model(&mut pruned, &calib, &cfg, None)?;
            let ppl = perplexity(&pruned, &eval_data, 128);
            print!("{ppl:>10.2}");
        }
        println!();
    }

    // the pipeline leaves an SM-pruned model packed in the sparse formats
    let mut pruned = Transformer { cfg: base.cfg, params: base.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SM,
        Sparsity::Unstructured { rate: 0.8 },
    ));
    let report = prune_model(&mut pruned, &calib, &cfg, None)?;
    let w = pruned.weight(0, "w1");
    println!(
        "\nblock0.w1 @80%: dense {} B -> {} {} B ({:.1}x smaller), nnz={}",
        w.dense_bytes(),
        w.format(),
        w.bytes(),
        w.dense_bytes() as f64 / w.bytes() as f64,
        w.nnz()
    );
    println!(
        "whole model: pruned linears {} B -> {} B ({:.2}x), eval runs the sparse kernels",
        report.dense_bytes(),
        report.packed_bytes(),
        report.compression_ratio()
    );
    println!("\nExpected shape (paper Table 2): at 80% SS/wanda blow up or");
    println!("collapse; SM degrades most gracefully (smallest ppl).");
    Ok(())
}
