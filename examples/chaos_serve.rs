//! Chaos smoke: the hardened HTTP front end under deliberate abuse, on
//! exactly the production code path (scripted wire faults, no test-only
//! control flow). Phases:
//!
//!   1. clean keep-alive workload — the baseline the chaos must not dent
//!   2. slow-loris client (trickled bytes, then a stall) -> typed `408`
//!   3. mid-stream client disconnect -> engine cancel, pages drain to 0
//!   4. pool saturation (2 workers, backlog 1) -> `503` + `Retry-After`
//!      at accept time, then everything queued still completes
//!
//! Every degraded connection must land in a typed counter, live K/V
//! pages must return to zero, and shutdown must reclaim every worker.
//!
//!     cargo run --release --example chaos_serve

use std::thread;
use std::time::{Duration, Instant};

use apt::model::{Transformer, TransformerConfig};
use apt::server::netfaults::{ConnScript, NetFaultPlan};
use apt::server::{client, Server, ServerConfig};
use apt::util::Rng;

/// Poll `/metrics` until `key == want` (the engine drains asynchronously).
fn await_metric(addr: std::net::SocketAddr, key: &str, want: usize) -> String {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = client::request(addr, "GET", "/metrics", None).expect("metrics");
        let text = String::from_utf8_lossy(&r.body).into_owned();
        if client::metric(&text, key) == Some(want) {
            return text;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {key} == {want}:\n{text}");
        thread::sleep(Duration::from_millis(20));
    }
}

fn metric(text: &str, key: &str) -> usize {
    client::metric(text, key).unwrap_or_else(|| panic!("metric {key} missing"))
}

fn main() {
    // untrained tiny model: the chaos smoke exercises plumbing, not text
    let vocab = 31;
    let model = Transformer::init(
        TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 128 },
        &mut Rng::new(11),
    );

    let cfg = ServerConfig {
        pool_workers: 2,
        conn_backlog: 1,
        read_timeout_ms: 150,
        header_deadline_ms: 400,
        ..ServerConfig::default()
    };

    // accept order: conn 0 is the clean keep-alive client, conn 1 the
    // slow loris, conn 2 the mid-stream disconnect; everything after
    // (saturation probes, metrics polls) runs on a clean wire
    let loris_raw = "POST /v1/generate HTTP/1.1\r\nContent-Length: 5\r\n\r\nab";
    let plan = NetFaultPlan::new()
        .on_conn(1, ConnScript::clean().trickle(1).stall_after(20))
        .on_conn(2, ConnScript::clean().drop_after(150));
    let h = Server::start_with_netfaults(model, "127.0.0.1:0", cfg, plan).expect("bind loopback");
    let addr = h.addr();
    println!("chaos target on http://{addr} (2 workers, backlog 1)");

    // -- phase 1: clean keep-alive workload --------------------------
    let body = r#"{"prompt": [1, 2, 3], "max_new_tokens": 6, "seed": 5}"#;
    let mut kc = client::Client::new(addr);
    for _ in 0..4 {
        let r = kc.request("POST", "/v1/generate", Some(body)).expect("clean generate");
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    }
    assert_eq!(kc.connects_made(), 1);
    println!("phase 1: 4 clean requests on 1 keep-alive connection -> all 200");
    drop(kc);

    // -- phase 2: slow loris -----------------------------------------
    // trickles 1 byte per read, stalls for good after byte 20 — the
    // header deadline fires and the server answers a typed 408
    let status = client::raw_roundtrip_status(addr, loris_raw).expect("loris response");
    assert_eq!(status, 408, "slow loris must get 408, not pin a worker");
    println!("phase 2: slow loris -> 408 request timeout");

    // -- phase 3: mid-stream disconnect ------------------------------
    // the wire drops dead 150 bytes into the response: headers clear,
    // the first token chunks do not — the server must cancel the stream
    let sbody = r#"{"prompt": [4, 5, 6], "max_new_tokens": 64, "stream": true}"#;
    let mut st = client::open_stream(addr, "/v1/generate", sbody).expect("open stream");
    assert_eq!(st.status, 200);
    let mut got = 0usize;
    while let Ok(Some(_)) = st.next_chunk() {
        got += 1;
    }
    drop(st);
    let text = await_metric(addr, "apt_engine_completions_cancelled_total", 1);
    assert_eq!(metric(&text, "apt_engine_kv_pages_live"), 0);
    println!("phase 3: wire cut mid-stream after {got} chunk(s) -> cancelled, 0 live pages");

    // -- phase 4: pool saturation ------------------------------------
    // freeze the engine so two streams pin both workers; one more
    // connection parks in the backlog, and the next is shed with 503 +
    // Retry-After at accept time without touching a worker
    h.pause_engine();
    let s1 = client::open_stream(addr, "/v1/generate", sbody).expect("pin worker 1");
    let s2 = client::open_stream(addr, "/v1/generate", sbody).expect("pin worker 2");
    thread::sleep(Duration::from_millis(100));
    let parked = thread::spawn(move || client::request(addr, "POST", "/v1/generate", Some(body)));
    thread::sleep(Duration::from_millis(150));
    let shed = client::request(addr, "POST", "/v1/generate", Some(body)).expect("shed response");
    assert_eq!(shed.status, 503, "{}", String::from_utf8_lossy(&shed.body));
    let retry = shed.header("retry-after").expect("Retry-After on 503").to_string();
    h.resume_engine();
    let parked = parked.join().expect("parked thread").expect("parked response");
    assert_eq!(parked.status, 200, "queued connection must still be served");
    for mut s in [s1, s2] {
        while let Ok(Some(_)) = s.next_chunk() {}
    }
    println!("phase 4: saturated pool -> 503 (Retry-After: {retry}), parked conn served after resume");

    // -- the ledger --------------------------------------------------
    let text = await_metric(addr, "apt_engine_kv_pages_live", 0);
    assert_eq!(metric(&text, "apt_engine_queue_depth"), 0);
    assert_eq!(metric(&text, "apt_engine_streams_active"), 0);
    assert_eq!(metric(&text, "apt_http_responses_408_total"), 1);
    assert_eq!(metric(&text, "apt_http_responses_503_shed_total"), 1);
    assert_eq!(metric(&text, "apt_http_stream_disconnects_total"), 1);
    assert_eq!(metric(&text, "apt_net_stalls_total"), 1);
    assert_eq!(metric(&text, "apt_net_disconnects_total"), 1);
    assert_eq!(metric(&text, "apt_net_short_io_conns_total"), 1);
    assert_eq!(metric(&text, "apt_engine_completions_cancelled_total"), 1);
    println!("ledger: every degraded connection in a typed counter, 0 live pages");

    let report = h.shutdown();
    assert_eq!(report.pool_workers_joined, 2, "shutdown must reclaim every pool worker");
    println!(
        "shutdown reclaimed {} workers; chaos_serve smoke passed",
        report.pool_workers_joined
    );
}
