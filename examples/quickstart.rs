//! Quickstart: prune ONE linear layer with every method and compare the
//! reconstruction error — the paper's math in 60 lines.
//!
//!     cargo run --release --example quickstart

use apt::prune::{
    prune_layer, quadratic_loss, HessianAccumulator, Method, PruneConfig, Sparsity,
};
use apt::tensor::Mat;
use apt::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // A layer w:(n=64, m=128) and some calibration activations X:(512, m).
    let w0 = Mat::randn(64, 128, 1.0, &mut rng);
    let x = Mat::randn(512, 128, 1.0, &mut rng);

    // Stream the activations into the layer Hessian H = 2 X^T X.
    let mut acc = HessianAccumulator::new(128);
    for chunk in 0..4 {
        let mut part = Mat::zeros(128, 128.min(x.cols));
        for r in 0..128 {
            part.row_mut(r).copy_from_slice(x.row(chunk * 128 + r));
        }
        acc.add_chunk(&part);
    }
    let hd = acc.damped(0.01);

    println!("pruning a (64 x 128) layer to 2:4 sparsity\n");
    println!("{:<16} {:>14} {:>12}", "method", "layer loss", "time (ms)");
    for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM, Method::MS, Method::MM]
    {
        let mut w = w0.clone();
        let cfg = PruneConfig::new(method, Sparsity::two_four());
        let res = prune_layer(&mut w, &acc, &cfg)?;
        let loss = quadratic_loss(&w0, &w, &hd);
        println!("{:<16} {:>14.3} {:>12.2}", method.name(), loss, res.elapsed_ms);
        assert!(res.mask.check_nm(2, 4));
    }

    println!("\nLower loss = better reconstruction of the layer output.");
    println!("Expected ordering: MM <= SM < MS/SS << wanda/magnitude.");
    Ok(())
}
