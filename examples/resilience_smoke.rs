//! Resilience smoke: a budget-constrained engine under scripted faults.
//!
//! Runs the same six-request workload twice — once clean and unbounded,
//! once with a 12-page K/V budget, an injected NaN, a forced recompute
//! preemption and a mid-flight cancel — and checks the degradation
//! contract end to end: every request finishes with a typed
//! `FinishReason`, untouched streams are bit-identical to the clean run,
//! the preempted stream resumes losslessly, and the engine reports every
//! event in its stats.
//!
//!     cargo run --release --example resilience_smoke

use apt::model::{Transformer, TransformerConfig};
use apt::serve::faults::FaultPlan;
use apt::serve::{
    Completion, Deadline, Engine, EngineConfig, EngineStats, ErrorKind, FinishReason, Request,
    RequestId, SamplingParams,
};
use apt::util::Rng;

fn main() {
    let vocab = 61usize;
    let model = Transformer::init(
        TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 256 },
        &mut Rng::new(9),
    );
    let prompt = |salt: usize, len: usize| -> Vec<u32> {
        (0..len).map(|i| ((i * 3 + salt * 13) % vocab) as u32).collect()
    };

    // Six requests against four slots: mixed prompt lengths, one
    // temperature-sampled stream, one with a 4-step deadline.
    let reqs: Vec<(Request, Deadline)> = vec![
        (Request::greedy(prompt(0, 12), 12), Deadline::none()),
        (Request::greedy(prompt(1, 10), 12), Deadline::none()),
        (
            Request {
                prompt: prompt(2, 14),
                max_new_tokens: 12,
                sampling: SamplingParams::temperature(0.9, 17),
            },
            Deadline::none(),
        ),
        (Request::greedy(prompt(3, 8), 12), Deadline::steps(4)),
        (Request::greedy(prompt(4, 16), 12), Deadline::none()),
        (Request::greedy(prompt(5, 9), 12), Deadline::none()),
    ];

    let run = |cfg: EngineConfig,
               plan: FaultPlan,
               cancel_at: Option<(RequestId, usize)>|
     -> (Vec<Completion>, EngineStats) {
        let mut eng = Engine::new(&model, cfg);
        for (req, dl) in &reqs {
            eng.submit_with_deadline(req.clone(), *dl);
        }
        eng.set_fault_plan(plan);
        let mut steps = 0usize;
        while eng.has_work() {
            eng.step();
            steps += 1;
            if let Some((id, at)) = cancel_at {
                if steps == at {
                    assert!(eng.cancel(id), "cancel target should still be live");
                }
            }
            assert!(
                cfg.max_kv_pages.map_or(true, |b| eng.kv_pages_live() <= b),
                "page budget violated after step {steps}"
            );
        }
        assert_eq!(eng.kv_pages_live(), 0, "drained engine must hold zero pages");
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        (done, eng.stats())
    };

    // Clean reference: no budget, no faults, no cancel.
    let clean_cfg = EngineConfig { max_batch: 4, ..Default::default() };
    let (base, base_st) = run(clean_cfg, FaultPlan::new(), None);
    assert_eq!(base.len(), reqs.len());
    assert_eq!(base_st.preemptions + base_st.quarantined + base_st.cancelled, 0);

    // Faulted run: 12-page budget (three streams' worth), NaN-poison one
    // stream after 4 tokens, force-preempt another after 3, cancel a
    // third mid-decode.
    let ids: Vec<RequestId> = base.iter().map(|c| c.id).collect();
    let plan = FaultPlan::new().nan_logits(ids[1], 4).force_preempt(ids[0], 3);
    let tight_cfg =
        EngineConfig { max_batch: 4, max_kv_pages: Some(12), ..Default::default() };
    let (done, st) = run(tight_cfg, plan, Some((ids[4], 16)));

    println!("faulted run, per-request outcomes:");
    for c in &done {
        println!("  {:?}: {:?} after {} tokens", c.id, c.finish, c.tokens.len());
    }

    // Every request finished, each with the expected typed reason.
    assert_eq!(done.len(), reqs.len());
    let finish = |i: usize| -> FinishReason { done[i].finish };
    assert_eq!(finish(0), FinishReason::Length, "preempted stream still completes");
    assert_eq!(done[0].tokens, base[0].tokens, "recompute preemption must be lossless");
    assert_eq!(finish(1), FinishReason::Error(ErrorKind::NonFiniteLogits));
    let n = done[1].tokens.len();
    assert_eq!(done[1].tokens[..], base[1].tokens[..n], "pre-poison prefix is kept");
    assert_eq!(finish(3), FinishReason::Deadline);
    assert_eq!(done[3].tokens, base[3].tokens, "deadline output matches the clean run");
    assert_eq!(finish(4), FinishReason::Cancelled);
    let n = done[4].tokens.len();
    assert!(n < 12, "cancel must land mid-decode");
    assert_eq!(done[4].tokens[..], base[4].tokens[..n], "partial output is kept");
    // untouched streams (including the sampled one): bit-identical
    for i in [2usize, 5] {
        assert_eq!(finish(i), FinishReason::Length);
        assert_eq!(done[i].tokens, base[i].tokens, "untouched stream {i} diverged");
    }

    println!(
        "\nengine stats: {} completed, {} preemptions, {} deadline, {} cancelled, \
         {} quarantined, kv pages peak {} (budget 12)",
        st.completed,
        st.preemptions,
        st.deadline_expired,
        st.cancelled,
        st.quarantined,
        st.kv_pages_peak
    );
    assert_eq!(st.completed, reqs.len());
    assert_eq!(st.preemptions, 1);
    assert_eq!(st.deadline_expired, 1);
    assert_eq!(st.cancelled, 1);
    assert_eq!(st.quarantined, 1);
    assert!(st.kv_pages_peak <= 12);

    println!("resilience_smoke: OK");
}
