//! END-TO-END DRIVER (DESIGN.md deliverable (b)): trains a transformer
//! from scratch on the synthetic corpus, logs the loss curve, prunes it
//! with every method at 50% unstructured AND 2:4 semi-structured through
//! the full L3 coordinator pipeline (optionally on the AOT/PJRT engine),
//! and reports the paper-style perplexity table. Recorded in
//! EXPERIMENTS.md SSE2E.
//!
//!     cargo run --release --example prune_transformer [hlo]

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::Profile;
use apt::eval::perplexity;
use apt::harness::Zoo;
use apt::model::{train, LanguageModel, TrainConfig, Transformer};
use apt::prune::{Method, PruneConfig, Sparsity};
use apt::runtime::{Backend, Runtime};

fn main() -> anyhow::Result<()> {
    let use_hlo = std::env::args().any(|a| a == "hlo");
    let zoo = Zoo::new(42);
    let runtime = if use_hlo {
        Some(Runtime::load(std::path::Path::new("artifacts"))?)
    } else {
        None
    };

    // ---- 1. train the dense model (logged loss curve)
    let cfg = zoo.transformer_config("llama", "small");
    let mut model = Transformer::init(cfg, &mut apt::util::Rng::new(42));
    println!("training microllama-small ({} params)...", model.n_params());
    let data = zoo.gen.generate(Profile::C4Like, 120_000, 43);
    let curve = train(
        &mut model,
        &data,
        &TrainConfig { steps: 400, batch: 8, seq_len: 64, log_every: 50, ..Default::default() },
    );
    println!("loss curve: {curve:.3?}");

    // ---- 2. evaluate dense
    let eval = |m: &dyn LanguageModel| -> (f64, f64, f64) {
        let wt2 = zoo.gen.generate(Profile::Wt2Like, 8_192, 7);
        let ptb = zoo.gen.generate(Profile::PtbLike, 8_192, 8);
        let c4 = zoo.gen.generate(Profile::C4Like, 8_192, 9);
        (
            perplexity(m, &wt2, 128),
            perplexity(m, &ptb, 128),
            perplexity(m, &c4, 128),
        )
    };
    let (wt2, ptb, c4) = eval(&model);
    println!("\n| method | sparsity | wt2 | ptb | c4 | engine |");
    println!("|---|---|---|---|---|---|");
    println!("| original | - | {wt2:.3} | {ptb:.3} | {c4:.3} | - |");

    // ---- 3. prune with every method through the coordinator
    let calib = zoo.calibration(Profile::C4Like, 32, 64);
    for sparsity in [Sparsity::Unstructured { rate: 0.5 }, Sparsity::two_four()] {
        let methods: &[Method] = match sparsity {
            Sparsity::Unstructured { .. } => {
                &[Method::Magnitude, Method::Wanda, Method::SS, Method::SM]
            }
            _ => &[Method::Magnitude, Method::Wanda, Method::SS, Method::SM, Method::MS, Method::MM],
        };
        for &method in methods {
            let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
            let pcfg = PipelineConfig::new(PruneConfig::new(method, sparsity)).with_engine(
                if use_hlo { Backend::Hlo } else { Backend::Native },
            );
            let report = prune_model(&mut pruned, &calib, &pcfg, runtime.as_ref())?;
            let (wt2, ptb, c4) = eval(&pruned);
            println!(
                "| {} | {} | {wt2:.3} | {ptb:.3} | {c4:.3} | {} |",
                method.name(),
                sparsity.label(),
                if report.hlo_fraction() > 0.0 { "hlo" } else { "native" }
            );
        }
    }
    println!("\nShape to verify vs the paper: SM <= SS on every dataset; at 2:4");
    println!("MM/SM beat SS; wanda/magnitude trail everything.");
    Ok(())
}
