//! Self-speculative decoding: prune → keep both → serve speculatively.
//!
//! Trains a tiny dense transformer, prunes a COPY of it into a draft
//! (`coordinator::prune_draft_model`), then serves greedy requests in
//! draft-propose / target-verify rounds. Greedy verification is
//! losslessly exact — the speculative output is asserted bit-identical
//! to plain dense decoding, both single-stream (`SpecSession` vs
//! `DecodeSession`) and batched (`spec_serve_report` runs the dense and
//! speculative engines on the same workload). Prints the acceptance
//! rate, tokens/round, and throughput on both sides.
//!
//!     cargo run --release --example spec_decode

use apt::coordinator::{prune_draft_model, PipelineConfig};
use apt::data::{CorpusGen, Profile};
use apt::eval::greedy_agreement;
use apt::model::{train, DecodeSession, TrainConfig, Transformer, TransformerConfig};
use apt::prune::{Method, PruneConfig, Sparsity};
use apt::serve::speculative::{spec_serve_report, SpecSession};
use apt::serve::EngineConfig;
use apt::util::Rng;

fn main() {
    let gen = CorpusGen::new(60, 2, 7);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let mut target = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 256 },
        &mut Rng::new(3),
    );
    train(
        &mut target,
        &data,
        &TrainConfig { steps: 60, batch: 8, seq_len: 32, log_every: 1000, ..Default::default() },
    );

    // draft = pruned copy of the target's own weights
    let mut draft = Transformer { cfg: target.cfg, params: target.params.clone() };
    let calib = data.sample_calibration(8, 32, &mut Rng::new(9));
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SS,
        Sparsity::Unstructured { rate: 0.5 },
    ));
    let report = prune_draft_model(&target, &mut draft, &calib, &cfg, None).unwrap();
    println!(
        "draft pruned to {:.0}% sparsity ({:.2}x compression)",
        report.overall_sparsity() * 100.0,
        report.compression_ratio()
    );
    let ws: Vec<&[u32]> = calib.iter().map(|c| c.as_slice()).collect();
    println!("offline greedy agreement (acceptance predictor): {:.3}", {
        greedy_agreement(&target, &draft, &ws)
    });

    // single-stream lossless gate: SpecSession vs plain dense session
    let prompt: Vec<u32> = (0..32).map(|i| ((i * 3 + 11) % vocab) as u32).collect();
    let mut plain = DecodeSession::new(&target);
    plain.prefill(&prompt);
    let want = plain.generate(24);
    for k in [1usize, 2, 4, 8] {
        let mut s = SpecSession::new(&target, &draft, k);
        s.prefill(&prompt);
        let got = s.generate(24);
        assert_eq!(got, want, "speculative output must be bit-identical (k={k})");
        let st = s.stats();
        println!(
            "k={k}: {} rounds, acceptance {:.3}, {:.2} tokens/round — lossless",
            st.rounds,
            st.acceptance_rate(),
            st.tokens_per_round()
        );
    }

    // batched engines: dense baseline vs speculative, same workload
    let prompts: Vec<Vec<u32>> = (0..6)
        .map(|i| (0..24 + 4 * i).map(|j| ((j * 3 + i * 11) % vocab) as u32).collect())
        .collect();
    let r = spec_serve_report(
        &target,
        &draft,
        &prompts,
        16,
        4,
        EngineConfig { max_batch: 4, ..Default::default() },
    );
    println!(
        "engine (k={}, {} streams): {} tokens, acceptance {:.3}, \
         dense {:.0} tok/s vs speculative {:.0} tok/s ({:.2}x)",
        r.k, r.streams, r.total_tokens, r.acceptance_rate, r.dense_tokens_per_s,
        r.spec_tokens_per_s, r.speedup
    );
    assert_eq!(r.total_tokens, prompts.len() * 16);
    println!("spec_decode: OK");
}
