//! Mamba scenario (paper SS5.2/5.3): prune micromamba with the LAMBADA-like
//! calibration set, then report perplexity AND the zero-shot suite —
//! reproducing Table 3's structure including the magnitude-collapse on the
//! LAMBADA-like task.
//!
//!     cargo run --release --example prune_mamba

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::Profile;
use apt::harness::suite::{eval_ppl_lambada, eval_zeroshot};
use apt::harness::Zoo;
use apt::model::{Mamba, MambaConfig};
use apt::model::LanguageModel as _;
use apt::prune::{Method, PruneConfig, Sparsity};

fn main() -> anyhow::Result<()> {
    let zoo = Zoo::new(42);
    let base = zoo.model("mamba", "small", 400)?;
    let apt::harness::AnyModel::Mamba(base) = base else { unreachable!() };
    println!("micromamba-small: {} params", base.n_params());

    let calib = zoo.calibration(Profile::LambadaLike, 32, 64);
    println!("\n| method | ppl-lambada | lambada-acc | hellaswag | avg(5 tasks) |");
    println!("|---|---|---|---|---|");

    let dense_ppl = eval_ppl_lambada(&base, &zoo);
    let dense_zs = eval_zeroshot(&base, &zoo, 120);
    println!(
        "| original | {dense_ppl:.3} | {:.1}% | {:.1}% | {:.2}% |",
        dense_zs.lambada * 100.0,
        dense_zs.hellaswag * 100.0,
        dense_zs.average() * 100.0
    );

    for method in [Method::Magnitude, Method::Wanda, Method::SS, Method::SM] {
        let mut pruned = Mamba { cfg: base.cfg, params: base.params.clone() };
        let cfg = PipelineConfig::new(PruneConfig::new(
            method,
            Sparsity::Unstructured { rate: 0.5 },
        ));
        prune_model(&mut pruned, &calib, &cfg, None)?;
        let ppl = eval_ppl_lambada(&pruned, &zoo);
        let zs = eval_zeroshot(&pruned, &zoo, 120);
        println!(
            "| {} | {ppl:.3} | {:.1}% | {:.1}% | {:.2}% |",
            method.name(),
            zs.lambada * 100.0,
            zs.hellaswag * 100.0,
            zs.average() * 100.0
        );
    }
    println!("\nPaper Sec 5.3's shape: magnitude collapses on the LAMBADA-like");
    println!("column (token prediction) while staying near chance on the");
    println!("multiple-choice columns; ours (SM) degrades least everywhere.");
    Ok(())
}
