//! Long-context smoke: window ≪ prompt length, forcing sustained
//! sliding-window eviction through the paged K/V path.
//!
//! A 600-token prompt decodes 100 more tokens under an 80-position
//! window (window straddles the 64-row page size, so whole pages are
//! freed and recycled continuously). Asserts the cache stays bounded at
//! the window throughout, the engine agrees with a windowed
//! single-stream session token-for-token, and every generated token
//! streams through the `on_token` hook.
//!
//!     cargo run --release --example long_context_smoke

use std::cell::Cell;

use apt::model::{DecodeSession, Transformer, TransformerConfig};
use apt::serve::{Engine, EngineConfig, Request};
use apt::util::{Rng, Timer};

fn main() {
    let vocab = 211usize;
    let model = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 1024 },
        &mut Rng::new(5),
    );
    let (window, prompt_len, new_toks) = (80usize, 600usize, 100usize);
    let prompt: Vec<u32> = (0..prompt_len).map(|i| ((i * 7 + 3) % vocab) as u32).collect();
    println!("window {window} ≪ prompt {prompt_len} (+{new_toks} generated): sustained eviction");

    let t = Timer::start();
    let streamed = Cell::new(0usize);
    let mut eng = Engine::new(&model, EngineConfig { max_batch: 2, max_seq: Some(window), ..Default::default() });
    eng.set_on_token(|_, _| streamed.set(streamed.get() + 1));
    eng.submit(Request::greedy(prompt.clone(), new_toks));
    while eng.has_work() {
        eng.step();
        for st in eng.states() {
            let cached = st.cached_len().unwrap_or(0);
            assert!(cached <= window, "cache {cached} exceeded window {window}");
        }
    }
    let done = eng.take_finished().remove(0);
    let engine_ms = t.elapsed_ms();
    assert_eq!(done.tokens.len(), new_toks);
    assert_eq!(streamed.get(), new_toks, "every token must stream through on_token");
    assert!(done.tokens.iter().all(|&t| (t as usize) < vocab));

    // the windowed single-stream session must agree token-for-token
    let mut s = DecodeSession::with_window(&model, window);
    s.prefill(&prompt);
    assert_eq!(s.generate(new_toks), done.tokens, "engine vs windowed session");

    println!(
        "{} prompt + {} generated tokens in {engine_ms:.1} ms, cache bounded at {window}",
        prompt.len(),
        done.tokens.len()
    );
    println!("long_context_smoke: OK");
}
