#!/usr/bin/env bash
# Run the perf microbenchmarks and refresh the perf trajectory file.
#
#   scripts/bench.sh [filter]
#
# Sections (substring filters): gemm hessian finalize cholesky compensate
# mrp select sequential mask24 sparse decode paged serve resilience
# speculative structured pipeline hlo server.
# `decode` covers both the pruned-model decode benches and the
# decode_session_* benches (incremental KV-cache/recurrent serving path
# vs the quadratic full-forward baseline, populating
# derived.decode_session_speedup_*). `paged` measures sliding-window
# K/V eviction (contiguous shift vs paged cursor), populating
# derived.decode_eviction_ns_per_step_{shift,paged}. `serve` runs the
# batched continuous-decoding engine at B ∈ {1, 4, 16} (dense +
# packed24 stores), populating
# derived.engine_throughput_tokens_per_s_{b1,b4,b16} and
# derived.engine_batch_speedup_{b4,b16} (plus *_packed24 variants), and
# also the cross-request packed-prefill and threaded batch-attention
# benches (derived.engine_prefill_packed_speedup,
# derived.batch_attn_thread_speedup). `speculative` serves the same
# greedy workload through the dense engine and the self-speculative one
# (magnitude-2:4 draft of the target's own weights) at k ∈ {2, 4, 8},
# populating derived.spec_decode_tokens_per_s_{dense,k2,k4,k8},
# derived.spec_acceptance_rate, and derived.spec_decode_speedup_vs_dense
# — the lossless gate (bit-identical outputs) is asserted before timing.
# `gemm` now also measures K-dimension cache tiling in `matmul_into`
# (untiled vs the default 128-column K tile, bitwise-identical output),
# populating derived.gemm_k_tiling_speedup. `structured` runs the
# structured-pruning pipeline (half the heads and FFN channels; every
# block linear a physically smaller dense matmul) against a
# magnitude-50% csr16 baseline on the same decode workload, populating
# derived.structured_decode_tokens_per_s,
# derived.structured_vs_csr_speedup and derived.structured_flops_ratio.
# `resilience` times the engine's degradation paths: cancelling
# mid-flight streams (page reclamation through the K/V freelist,
# derived.engine_cancel_reclaim_ns per stream) and finishing an
# over-budget workload under a tight max_kv_pages via recompute
# preemption vs the same workload unconstrained
# (derived.engine_preempt_recompute_overhead, a wall-clock ratio).
# `server` runs the separate loadgen bench binary against the HTTP
# front end over loopback: a closed-loop generator (8 clients,
# back-to-back requests) for derived.server_p50_latency_ms,
# derived.server_p99_latency_ms and derived.server_tokens_per_s, the
# same closed loop down reused keep-alive connections for
# derived.server_keepalive_speedup, an open-loop generator at 2x the
# measured capacity for derived.server_429_rate (the bounded pending
# queue's refusal fraction under honest overload — the open loop exists
# because a closed generator coordinates with server state and omits
# exactly the arrivals that would have queued), and a misbehaving-client
# pack (slow-loris connections vs a short-timeout server) for
# derived.server_shed_rate_misbehaving (fraction put down with a typed
# 408/503 while honest traffic completes alongside).
#
# The bench binary itself writes BENCH_perf.json at the repo root and
# prints a delta table against the previous run (a filtered run keeps the
# previous numbers for kernels it didn't re-measure), so this wrapper only
# pins the working directory and forwards arguments.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench perf -- "$@"

# the HTTP load harness is its own binary (it owns a server lifecycle,
# not a kernel loop); runs unfiltered or under the `server` filter and
# merges its keys into the same trajectory file
case "${1:-}" in
  "" | server)
    echo
    cargo bench --bench loadgen
    ;;
esac

echo
echo "perf trajectory: $(pwd)/BENCH_perf.json"
