#!/usr/bin/env bash
# Tier-1 verification + hygiene gate. Run locally before pushing, and by
# .github/workflows/ci.yml on every push/PR:
#
#   scripts/ci.sh
#
# Tier-1 (ROADMAP.md): cargo build --release && cargo test -q
# Hygiene: rustfmt drift check (requires the rustfmt component).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: build (release) =="
cargo build --release

echo "== tier-1: tests =="
cargo test -q

echo "== smoke: serving engine example =="
cargo run --release --example serve_engine

echo "== smoke: long context (window << prompt, sustained paged eviction) =="
cargo run --release --example long_context_smoke

echo "== smoke: speculative decoding (lossless draft-propose / target-verify) =="
cargo run --release --example spec_decode

echo "== smoke: structured pruning (reduced-shape dense stores end to end) =="
cargo run --release --example structured_prune

echo "== smoke: engine resilience (page budget + injected faults, typed completions) =="
cargo run --release --example resilience_smoke

echo "== smoke: HTTP serving front end (loopback generate/stream/metrics, graceful drain) =="
cargo run --release --example http_serve

echo "== smoke: HTTP chaos (slow loris, mid-stream disconnect, pool saturation, typed counters) =="
cargo run --release --example chaos_serve

echo "== hygiene: rustfmt check =="
cargo fmt --all -- --check

echo "ci.sh: all checks passed"
