"""AOT lowering smoke tests: every entry point lowers to valid HLO text."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as L2


class TestLowering:
    @pytest.mark.parametrize("name", list(L2.entry_points(8, 8, 8, 4).keys()))
    def test_entry_lowers_to_hlo_text(self, name):
        fn, ex = L2.entry_points(16, 16, 16, 8)[name]
        text = aot.to_hlo_text(fn, ex)
        assert text.startswith("HloModule"), text[:80]
        assert "ROOT" in text

    def test_manifest_roundtrip(self, tmp_path):
        aot.main(["--out", str(tmp_path), "--shapes", "8,8,8", "--only", "prune_24_sm"])
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["format"] == "hlo-text-v1"
        assert len(man["entries"]) == 1
        e = man["entries"][0]
        assert e["name"] == "prune_24_sm"
        assert (tmp_path / e["file"]).exists()
        assert e["inputs"][0]["shape"] == [8, 8]

    def test_shapes_flag_parsing(self, tmp_path):
        aot.main(
            ["--out", str(tmp_path), "--shapes", "8,8,8;16,8,8", "--only", "hessian_update"]
        )
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert len(man["entries"]) == 2
