"""L1 kernel correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes/dtypes per the session's testing policy; every
kernel is asserted allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.hessian import hessian_damped, hessian_xtx
from compile.kernels.mask24 import extract_diag_blocks4, solution_m_mask24
from compile.kernels.score import solution_s_scores
from compile.kernels import ref

RNG = np.random.default_rng(0)


def rand(shape, scale=1.0, rng=RNG):
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


def spd_hinv(m, rng=RNG):
    """A well-conditioned SPD matrix standing in for (2XtX+gI)^-1."""
    a = rng.normal(size=(m, 2 * m)).astype(np.float64)
    h = 2.0 * a @ a.T + 0.05 * np.trace(a @ a.T) / m * np.eye(m)
    return jnp.asarray(np.linalg.inv(h).astype(np.float32))


# ---------------------------------------------------------------------------
# hessian kernel
# ---------------------------------------------------------------------------

class TestHessian:
    def test_matches_ref_basic(self):
        x = rand((128, 128))
        assert_allclose(hessian_xtx(x), ref.ref_hessian(x), rtol=2e-4, atol=2e-4)

    def test_matches_ref_multi_tile(self):
        x = rand((256, 256))
        got = hessian_xtx(x, bm=128, bt=64)
        assert_allclose(got, ref.ref_hessian(x), rtol=3e-4, atol=3e-4)

    def test_symmetric(self):
        x = rand((64, 64))
        h = np.asarray(hessian_xtx(x))
        assert_allclose(h, h.T, rtol=1e-5, atol=1e-5)

    def test_damped_adds_gamma_mean_diag(self):
        x = rand((64, 64))
        h0 = np.asarray(hessian_xtx(x))
        hd = np.asarray(hessian_damped(x, gamma=0.01))
        expect = h0 + 0.01 * np.mean(np.diag(h0)) * np.eye(64)
        assert_allclose(hd, expect, rtol=1e-5, atol=1e-5)

    def test_psd(self):
        x = rand((96, 32))
        evs = np.linalg.eigvalsh(np.asarray(hessian_xtx(x, bm=32, bt=32), dtype=np.float64))
        assert evs.min() >= -1e-3

    @settings(deadline=None, max_examples=12)
    @given(
        t_tiles=st.integers(1, 3),
        m_tiles=st.integers(1, 3),
        tile=st.sampled_from([8, 16, 32]),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_hypothesis_shapes(self, t_tiles, m_tiles, tile, scale):
        rng = np.random.default_rng(t_tiles * 100 + m_tiles * 10 + tile)
        x = rand((t_tiles * tile, m_tiles * tile), scale=scale, rng=rng)
        got = hessian_xtx(x, bm=tile, bt=tile)
        assert_allclose(got, ref.ref_hessian(x), rtol=1e-3, atol=1e-3 * scale * scale)


# ---------------------------------------------------------------------------
# score kernel (Eq. 14)
# ---------------------------------------------------------------------------

class TestScores:
    def test_matches_ref(self):
        w = rand((128, 64))
        d = jnp.abs(rand((64,))) + 0.1
        assert_allclose(
            solution_s_scores(w, d), ref.ref_scores(w, d), rtol=1e-5, atol=1e-6
        )

    def test_zero_weight_zero_score(self):
        w = jnp.zeros((16, 16))
        d = jnp.ones((16,))
        assert float(jnp.max(solution_s_scores(w, d, bn=16))) == 0.0

    def test_scale_invariance_relation(self):
        # score(c*w) = c^2 * score(w)
        w = rand((32, 32))
        d = jnp.abs(rand((32,))) + 0.1
        s1 = np.asarray(solution_s_scores(w, d, bn=32))
        s2 = np.asarray(solution_s_scores(3.0 * w, d, bn=32))
        assert_allclose(s2, 9.0 * s1, rtol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(n=st.sampled_from([8, 32, 128]), m=st.sampled_from([4, 64, 256]))
    def test_hypothesis_shapes(self, n, m):
        rng = np.random.default_rng(n * 1000 + m)
        w = rand((n, m), rng=rng)
        d = jnp.abs(rand((m,), rng=rng)) + 0.05
        got = solution_s_scores(w, d, bn=min(8, n))
        assert_allclose(got, ref.ref_scores(w, d), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# 2:4 Solution-M mask kernel (Eq. 12)
# ---------------------------------------------------------------------------

class TestMask24:
    def _setup(self, n, m, seed=0):
        rng = np.random.default_rng(seed)
        w = rand((n, m), rng=rng)
        hinv = spd_hinv(m, rng=rng)
        hb = extract_diag_blocks4(hinv)
        return w, hinv, hb

    def test_matches_ref(self):
        w, _, hb = self._setup(64, 64)
        mask, loss = solution_m_mask24(w, hb, bn=32)
        rmask, rloss = ref.ref_mask24(w, hb)
        assert_allclose(mask, rmask)
        assert_allclose(loss, rloss, rtol=1e-4, atol=1e-6)

    def test_exactly_2_per_group(self):
        w, _, hb = self._setup(32, 128, seed=3)
        mask, _ = solution_m_mask24(w, hb, bn=32)
        per_group = np.asarray(mask).reshape(32, 32, 4).sum(axis=2)
        assert (per_group == 2.0).all()

    def test_mask_loss_is_group_minimum(self):
        # brute force: every other combo in every group has >= loss.
        w, _, hb = self._setup(8, 16, seed=5)
        mask, loss = solution_m_mask24(w, hb, bn=8)
        wn, hbn = np.asarray(w), np.asarray(hb)
        for r in range(8):
            for g in range(4):
                for (a, b) in ref.COMBOS_2_4:
                    l = ref.ref_group_loss_2of4(wn[r, 4 * g:4 * g + 4], hbn[g], a, b)
                    assert float(l) >= float(loss[r, g]) - 1e-5

    def test_diag_blocks_extraction(self):
        hinv = spd_hinv(16)
        hb = np.asarray(extract_diag_blocks4(hinv))
        hn = np.asarray(hinv)
        for g in range(4):
            assert_allclose(hb[g], hn[4 * g:4 * g + 4, 4 * g:4 * g + 4])

    @settings(deadline=None, max_examples=8)
    @given(n=st.sampled_from([8, 16, 64]), g=st.sampled_from([2, 8, 16]), seed=st.integers(0, 99))
    def test_hypothesis_shapes(self, n, g, seed):
        w, _, hb = self._setup(n, 4 * g, seed=seed)
        mask, loss = solution_m_mask24(w, hb, bn=min(8, n))
        rmask, rloss = ref.ref_mask24(w, hb)
        assert_allclose(loss, rloss, rtol=1e-4, atol=1e-6)
        # Masks can differ only on exact loss ties; compare losses instead,
        # plus structural 2-per-4 validity.
        per_group = np.asarray(mask).reshape(n, g, 4).sum(axis=2)
        assert (per_group == 2.0).all()
