"""L2 prune-graph correctness + the paper's core mathematical claims.

Beyond kernel-vs-oracle equality this asserts the *theory*:
  - constraint satisfaction: (w + dw) is exactly zero at pruned entries
  - Eq. (12) predicted loss == achieved 1/2 dw H dw^T (optimality identity)
  - Solution-M compensation <= sequential SparseGPT comp <= plain zeroing
    (the paper's Sec. 4.4 ordering), for identical masks
  - MM group mask <= SM group mask in Eq. (12) loss
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as L2
from compile.kernels import ref

RNG = np.random.default_rng(7)


def make_layer(n, m, t=None, seed=0):
    """Random layer + calibration activations + damped H, Hinv."""
    rng = np.random.default_rng(seed)
    t = t or 4 * m
    w = rng.normal(size=(n, m)).astype(np.float32)
    x = rng.normal(size=(t, m)).astype(np.float32)
    h = 2.0 * x.astype(np.float64).T @ x.astype(np.float64)
    h += 0.01 * np.mean(np.diag(h)) * np.eye(m)
    hinv = np.linalg.inv(h)
    return (
        jnp.asarray(w),
        jnp.asarray(x),
        jnp.asarray(h.astype(np.float32)),
        jnp.asarray(hinv.astype(np.float32)),
    )


class TestHessianGraphs:
    def test_update_accumulates(self):
        w, x, h, hinv = make_layer(8, 32, t=64)
        h0 = jnp.zeros((32, 32))
        (h1,) = L2.hessian_update(x[:32], h0)
        (h2,) = L2.hessian_update(x[32:], h1)
        assert_allclose(h2, ref.ref_hessian(x), rtol=2e-4, atol=2e-3)

    def test_finalize_inverts(self):
        _, x, _, _ = make_layer(8, 16, t=64)
        h = ref.ref_hessian(x)
        (hinv,) = L2.hessian_finalize(h, jnp.float32(0.01))
        hd = np.asarray(ref.ref_hessian(x, gamma=0.01), dtype=np.float64)
        assert_allclose(np.asarray(hinv, dtype=np.float64) @ hd, np.eye(16), atol=2e-2)


class TestUnstructuredSM:
    def test_matches_ref(self):
        w, x, h, hinv = make_layer(16, 32, seed=1)
        w_new, loss = L2.prune_unstructured_sm(w, hinv, k=16)
        rw, rloss, _ = ref.ref_prune_unstructured_sm(w, hinv, 16)
        assert_allclose(w_new, rw, rtol=2e-3, atol=2e-3)
        assert_allclose(loss, rloss, rtol=2e-3)

    def test_sparsity_exact(self):
        w, x, h, hinv = make_layer(16, 64, seed=2)
        w_new, _ = L2.prune_unstructured_sm(w, hinv, k=32)
        zeros_per_row = (np.asarray(w_new) == 0.0).sum(axis=1)
        assert (zeros_per_row >= 32).all()

    def test_predicted_equals_achieved_loss(self):
        # Eq. (12) == 1/2 dw H dw^T: the optimality identity.
        w, x, h, hinv = make_layer(12, 24, seed=3)
        w_new, loss = L2.prune_unstructured_sm(w, hinv, k=12)
        hd = ref.ref_hessian(x, gamma=0.01)
        achieved = ref.ref_quadratic_loss(w, w_new, hd)
        assert_allclose(float(loss), float(achieved), rtol=5e-2)

    def test_compensation_beats_plain_zeroing(self):
        w, x, h, hinv = make_layer(12, 24, seed=4)
        w_new, loss = L2.prune_unstructured_sm(w, hinv, k=12)
        mask = (np.asarray(w_new) == 0.0) & (np.asarray(w) != 0.0)
        hd = ref.ref_hessian(x, gamma=0.01)
        zero_loss = ref.ref_zeroing_loss(w, jnp.asarray(mask.astype(np.float32)), hd)
        assert float(loss) <= float(zero_loss) * (1 + 1e-4)


class TestSemiStructured:
    @pytest.mark.parametrize("fn", [L2.prune_24_sm, L2.prune_24_mm])
    def test_24_structure(self, fn):
        w, x, h, hinv = make_layer(16, 32, seed=5)
        out = fn(w, hinv)
        w_new = np.asarray(out[0])
        per_group = (w_new.reshape(16, 8, 4) == 0.0).sum(axis=2)
        assert (per_group >= 2).all()

    def test_sm_matches_ref(self):
        w, x, h, hinv = make_layer(8, 16, seed=6)
        w_new, loss = L2.prune_24_sm(w, hinv)
        rw, rloss, _ = ref.ref_prune_24_sm(w, hinv)
        assert_allclose(w_new, rw, rtol=2e-3, atol=2e-3)

    def test_mm_matches_ref(self):
        w, x, h, hinv = make_layer(8, 16, seed=7)
        w_new, loss = L2.prune_24_mm(w, hinv)
        rw, rloss, _ = ref.ref_prune_24_mm(w, hinv)
        assert_allclose(w_new, rw, rtol=2e-3, atol=2e-3)

    def test_mm_mask_loss_leq_sm_mask_loss(self):
        # The Eq. (12)-selected mask is optimal in the *group-local* metric
        # (the paper's Sec. 4.2.1 per-group simplification: groups are
        # scored by the 4x4 diagonal block of Hinv, so optimality holds in
        # that metric; cross-group interactions may reorder the full loss,
        # which is why Table 1 occasionally shows MS > SS).
        from compile.kernels.mask24 import extract_diag_blocks4

        for seed in range(5):
            w, x, h, hinv = make_layer(8, 32, seed=20 + seed)
            hb = np.asarray(extract_diag_blocks4(hinv))
            wn = np.asarray(w)

            def group_metric_loss(idx):
                total = 0.0
                for r in range(wn.shape[0]):
                    cols = np.asarray(idx[r]).reshape(-1, 2)  # 2 per group
                    for (ca, cb) in cols:
                        g = ca // 4
                        total += float(
                            ref.ref_group_loss_2of4(
                                wn[r, 4 * g:4 * g + 4], hb[g], ca % 4, cb % 4
                            )
                        )
                return total

            _, _, idx_mm = ref.ref_prune_24_mm(w, hinv)
            _, _, idx_sm = ref.ref_prune_24_sm(w, hinv)
            assert group_metric_loss(idx_mm) <= group_metric_loss(idx_sm) * (1 + 1e-6)


class TestSequentialCompensation:
    def test_matches_ref_sparsegpt(self):
        w, x, h, hinv = make_layer(8, 16, seed=8)
        mask = (RNG.random((8, 16)) < 0.5).astype(np.float32)
        (w_new,) = L2.prune_seq_given_mask(w, jnp.asarray(mask), hinv)
        rw = ref.ref_sparsegpt_compensate(w, jnp.asarray(mask), hinv)
        assert_allclose(w_new, rw, rtol=5e-3, atol=5e-3)

    def test_pruned_entries_zero(self):
        w, x, h, hinv = make_layer(8, 16, seed=9)
        mask = (RNG.random((8, 16)) < 0.3).astype(np.float32)
        (w_new,) = L2.prune_seq_given_mask(w, jnp.asarray(mask), hinv)
        assert (np.asarray(w_new)[mask > 0] == 0.0).all()

    def test_mrp_beats_sequential_same_mask(self):
        # Paper Sec 4.4: updating ALL unpruned weights (Solution M) achieves
        # lower quadratic loss than sequential freezing (Solution S).
        for seed in range(5):
            w, x, h, hinv = make_layer(8, 24, seed=30 + seed)
            hd = ref.ref_hessian(x, gamma=0.01)
            k = 12
            _, _, idx = ref.ref_prune_unstructured_sm(w, hinv, k)
            mask = np.zeros((8, 24), dtype=np.float32)
            np.put_along_axis(mask, np.asarray(idx), 1.0, axis=1)
            w_m, loss_m = ref.ref_compensate(w, idx, hinv)[0], None
            w_m2, pred = ref.ref_compensate(w, idx, hinv)
            w_s = ref.ref_sparsegpt_compensate(w, jnp.asarray(mask), hinv)
            am = float(ref.ref_quadratic_loss(w, w_m2, hd))
            as_ = float(ref.ref_quadratic_loss(w, w_s, hd))
            assert am <= as_ * (1 + 1e-3), (seed, am, as_)
