"""Pure-HLO batched linear algebra for the AOT graphs.

jnp.linalg.{solve,cholesky,inv} lower to LAPACK FFI custom-calls on CPU
(e.g. "lapack_spotrf_ffi") which xla_extension 0.5.1 — the runtime behind
the rust `xla` crate — does not register. These replacements lower to plain
HLO (while-loops + elementwise + dynamic slices) so the artifacts run on
any PJRT backend.

All routines are batched over the leading axis and assume SPD inputs (the
Hinv principal sub-matrices of the pruning math are SPD by construction).
"""

import jax
import jax.numpy as jnp


def batched_cholesky(a):
    """Lower-triangular L with a = L L^T, a:(..., k, k) SPD.

    Outer-product Cholesky: k iterations of rank-1 downdates, each a
    vectorized (batched) elementwise step — no LAPACK.
    """
    k = a.shape[-1]
    ar = jnp.arange(k)

    def body(j, carry):
        acur, l = carry
        d = jnp.sqrt(acur[..., j, j])  # (...,)
        col = acur[..., :, j] / d[..., None]  # (..., k)
        col = jnp.where(ar >= j, col, 0.0)
        l = l.at[..., :, j].set(col)
        acur = acur - col[..., :, None] * col[..., None, :]
        return (acur, l)

    _, l = jax.lax.fori_loop(0, k, body, (a, jnp.zeros_like(a)))
    return l


def batched_solve_lower(l, b):
    """Solve L y = b for lower-triangular L:(...,k,k), b:(...,k)."""
    k = l.shape[-1]
    ar = jnp.arange(k)

    def body(j, y):
        # y_j = (b_j - sum_{i<j} L[j,i] y_i) / L[j,j]
        dot = jnp.sum(jnp.where(ar < j, l[..., j, :] * y, 0.0), axis=-1)
        yj = (b[..., j] - dot) / l[..., j, j]
        return y.at[..., j].set(yj)

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def batched_solve_lower_t(l, y):
    """Solve L^T x = y for lower-triangular L."""
    k = l.shape[-1]
    ar = jnp.arange(k)

    def body(i, x):
        j = k - 1 - i
        dot = jnp.sum(jnp.where(ar > j, l[..., :, j] * x, 0.0), axis=-1)
        xj = (y[..., j] - dot) / l[..., j, j]
        return x.at[..., j].set(xj)

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(y))


def batched_spd_solve(a, b):
    """Solve a x = b for SPD a:(...,k,k), b:(...,k) via Cholesky."""
    l = batched_cholesky(a)
    return batched_solve_lower_t(l, batched_solve_lower(l, b))


def spd_inverse(a):
    """Inverse of SPD a:(k,k) by solving against the identity columns."""
    k = a.shape[-1]
    eye = jnp.eye(k, dtype=a.dtype)
    # batch over columns: solve a x_i = e_i
    cols = jax.vmap(lambda e: batched_spd_solve(a, e))(eye)  # (k, k) rows=solutions
    return cols.T


def cholesky_upper(a):
    """Upper factor U with a = U^T U (the SparseGPT sweep wants this)."""
    return jnp.swapaxes(batched_cholesky(a), -1, -2)
