"""L1 Pallas kernel: tiled damped-Hessian accumulation H = 2 * X^T X.

TPU mapping (DESIGN.md SS3 "Hardware adaptation"): the rank-B update chain
the paper runs as a cuBLAS GEMM becomes an MXU-tiled GEMM with the output
tile resident in VMEM across the batch-chunk grid axis. The grid is
(row_tiles, col_tiles, batch_chunks); because the batch axis is the
innermost (sequential) grid dimension, `o_ref` for a given (i, j) tile
persists across the k-steps and we accumulate in place — the classic
"revisiting output" Pallas accumulation pattern. HBM->VMEM streaming of the
two X tiles is expressed by the BlockSpec index maps; on a real TPU the
Mosaic pipeline double-buffers them automatically.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hessian_kernel(xi_ref, xj_ref, o_ref):
    """One (bt, bm) x (bt, bm) -> (bm, bm) accumulation step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...]
    xj = xj_ref[...]
    # f32 accumulation regardless of input dtype (MXU-native behaviour).
    o_ref[...] += 2.0 * jax.lax.dot_general(
        xi, xj,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bt"))
def hessian_xtx(x, bm=128, bt=128):
    """2 * X^T X for X:(T, m) via the tiled Pallas kernel.

    bm: output tile edge (VMEM: 2 tiles of bt*bm inputs + bm*bm out).
    bt: batch-chunk length streamed per grid step.
    """
    t, m = x.shape
    bm = min(bm, m)
    bt = min(bt, t)
    assert m % bm == 0 and t % bt == 0, (t, m, bt, bm)
    grid = (m // bm, m // bm, t // bt)
    return pl.pallas_call(
        _hessian_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bt, bm), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=True,
    )(x, x)


def hessian_damped(x, gamma, bm=128, bt=128):
    """H = 2 X^T X + gamma * mean(diag) * I (Remark 4.1 dampening)."""
    h = hessian_xtx(x, bm=bm, bt=bt)
    damp = gamma * jnp.mean(jnp.diag(h))
    return h + damp * jnp.eye(x.shape[1], dtype=h.dtype)
