"""Pure-jnp reference oracles for the L1 Pallas kernels and L2 graphs.

Every kernel in this package has an oracle here; pytest asserts
`assert_allclose(kernel(...), ref(...))`. The oracles follow the paper's
equations literally (Zhao et al., EMNLP 2024 Findings):

  Eq. (12)  L* = 1/2 * sum_i  w_qi . inv(Hinv[P_i,P_i]) . w_qi^T
  Eq. (13)  dw[q_i,:] = - w_qi . inv(Hinv[P_i,P_i]) . Hinv[P_i,:]
  Eq. (14)  Lhat   = w_ij^2 / (2 * Hinv[j,j])          (Solution S score)

with Hinv = (2 X^T X + gamma I)^{-1} ("2xx^T" in the paper's m x B
convention; we carry activations as (T, m) token-rows).
"""

from itertools import combinations

import jax.numpy as jnp
import numpy as np

# The 6 ways of pruning 2 weights out of a group of 4 (2:4 sparsity).
COMBOS_2_4 = list(combinations(range(4), 2))  # [(0,1),(0,2),...,(2,3)]


def ref_hessian(x, gamma=0.0):
    """Damped layer Hessian H = 2 X^T X + gamma*mean(diag)*I for X:(T,m)."""
    h = 2.0 * x.T @ x
    if gamma:
        damp = gamma * jnp.mean(jnp.diag(h))
        h = h + damp * jnp.eye(x.shape[1], dtype=x.dtype)
    return h


def ref_scores(w, hinv_diag):
    """Eq. (14): per-weight Solution-S pruning loss w^2 / (2*diag(Hinv))."""
    return (w * w) / (2.0 * hinv_diag[None, :])


def ref_group_loss_2of4(w_group, hinv_block, a, b):
    """Eq. (12) for one row-group: prune columns {a,b} of a 4-wide group.

    w_group:(4,)  hinv_block:(4,4) = Hinv restricted to the group's columns.
    Uses the closed-form 2x2 inverse.
    """
    s11 = hinv_block[a, a]
    s22 = hinv_block[b, b]
    s12 = hinv_block[a, b]
    det = s11 * s22 - s12 * s12
    wa, wb = w_group[a], w_group[b]
    return 0.5 * (wa * wa * s22 - 2.0 * wa * wb * s12 + wb * wb * s11) / det


def ref_mask24(w, hinv_blocks):
    """Solution-M 2:4 mask (Eq. 12 enumerated over the 6 combos per group).

    w:(n,m), hinv_blocks:(m//4,4,4) diagonal 4x4 blocks of Hinv.
    Returns (mask, loss): mask (n,m) with 1.0 at pruned entries, exactly 2
    per 4-group; loss (n, m//4) the minimal group loss.
    """
    n, m = w.shape
    g = m // 4
    wg = np.asarray(w, dtype=np.float64).reshape(n, g, 4)
    hb = np.asarray(hinv_blocks, dtype=np.float64)
    losses = np.empty((len(COMBOS_2_4), n, g))
    for ci, (a, b) in enumerate(COMBOS_2_4):
        s11 = hb[:, a, a][None, :]
        s22 = hb[:, b, b][None, :]
        s12 = hb[:, a, b][None, :]
        det = s11 * s22 - s12 * s12
        wa, wb = wg[:, :, a], wg[:, :, b]
        losses[ci] = 0.5 * (wa * wa * s22 - 2 * wa * wb * s12 + wb * wb * s11) / det
    best = np.argmin(losses, axis=0)  # (n, g)
    mask = np.zeros((n, g, 4), dtype=np.float32)
    for ci, (a, b) in enumerate(COMBOS_2_4):
        sel = best == ci
        mask[:, :, a] += sel
        mask[:, :, b] += sel
    minloss = np.min(losses, axis=0).astype(np.float32)
    return jnp.asarray(mask.reshape(n, m)), jnp.asarray(minloss)


def ref_compensate(w, idx, hinv):
    """Eq. (13) optimal Solution-M compensation, row by row.

    w:(n,m), idx:(n,k) pruned column indices per row, hinv:(m,m).
    Returns (w_new, pred_loss) with w_new exactly zero at pruned entries and
    pred_loss the Eq. (12) total.
    """
    wn = np.array(w, dtype=np.float64)
    hi = np.asarray(hinv, dtype=np.float64)
    n, _ = wn.shape
    total = 0.0
    out = wn.copy()
    for r in range(n):
        p = np.asarray(idx[r])
        sub = hi[np.ix_(p, p)]
        rhs = wn[r, p]
        lam = np.linalg.solve(sub, rhs)
        out[r] -= lam @ hi[p, :]
        out[r, p] = 0.0
        total += 0.5 * float(rhs @ lam)
    return jnp.asarray(out.astype(np.float32)), jnp.float32(total)


def ref_sparsegpt_compensate(w, mask, hinv):
    """Solution-S compensation: SparseGPT/OBC sequential column sweep.

    Processes columns left->right using the Cholesky factor of Hinv; all
    columns before the current one are frozen (the paper's Sec. 2.3.2).
    mask:(n,m) 1.0 = prune. Returns w_new (pruned entries exactly zero).
    """
    wn = np.array(w, dtype=np.float64)
    hi = np.asarray(hinv, dtype=np.float64)
    mk = np.asarray(mask)
    u = np.linalg.cholesky(hi).T  # upper triangular, hinv = u.T @ u
    n, m = wn.shape
    for j in range(m):
        d = u[j, j]
        err = (wn[:, j] * mk[:, j]) / d
        wn[:, j:] -= np.outer(err, u[j, j:])
        wn[mk[:, j] > 0, j] = 0.0
    return jnp.asarray(wn.astype(np.float32))


def ref_zeroing_loss(w, mask, h):
    """Loss of pruning WITHOUT compensation: dw = -w at pruned entries.

    L = 1/2 dw H dw^T summed over rows (the magnitude-pruning loss under
    the same quadratic metric).
    """
    dw = -np.asarray(w, dtype=np.float64) * np.asarray(mask, dtype=np.float64)
    hh = np.asarray(h, dtype=np.float64)
    return jnp.float32(0.5 * float(np.sum((dw @ hh) * dw)))


def ref_quadratic_loss(w_before, w_after, h):
    """Achieved loss 1/2 * sum_rows (dw H dw^T) for dw = after - before."""
    dw = np.asarray(w_after, dtype=np.float64) - np.asarray(w_before, dtype=np.float64)
    hh = np.asarray(h, dtype=np.float64)
    return jnp.float32(0.5 * float(np.sum((dw @ hh) * dw)))


def ref_prune_unstructured_sm(w, hinv, k):
    """Solution-S mask (Eq. 14, per-row top-k) + Solution-M compensation."""
    scores = np.asarray(ref_scores(w, jnp.diag(hinv)))
    idx = np.argsort(scores, axis=1, kind="stable")[:, :k]
    idx = np.sort(idx, axis=1)
    w_new, loss = ref_compensate(w, jnp.asarray(idx), hinv)
    return w_new, loss, jnp.asarray(idx)


def ref_prune_24_sm(w, hinv):
    """Solution-S mask restricted to 2-per-4 groups + Solution-M comp."""
    n, m = w.shape
    scores = np.asarray(ref_scores(w, jnp.diag(hinv))).reshape(n, m // 4, 4)
    order = np.argsort(scores, axis=2, kind="stable")[:, :, :2]  # (n,g,2)
    base = (np.arange(m // 4) * 4)[None, :, None]
    idx = np.sort((order + base).reshape(n, m // 2), axis=1)
    w_new, loss = ref_compensate(w, jnp.asarray(idx), hinv)
    return w_new, loss, jnp.asarray(idx)


def ref_prune_24_mm(w, hinv):
    """Solution-M mask (Eq. 12 enumeration) + Solution-M compensation."""
    g = w.shape[1] // 4
    hb = np.stack(
        [np.asarray(hinv)[i * 4:(i + 1) * 4, i * 4:(i + 1) * 4] for i in range(g)]
    )
    mask, _ = ref_mask24(w, jnp.asarray(hb))
    idx = np.argsort(-np.asarray(mask), axis=1, kind="stable")[:, : w.shape[1] // 2]
    idx = np.sort(idx, axis=1)
    w_new, loss = ref_compensate(w, jnp.asarray(idx), hinv)
    return w_new, loss, jnp.asarray(idx)
