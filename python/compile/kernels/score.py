"""L1 Pallas kernel: Solution-S pruning scores (paper Eq. 14).

score[i, j] = w[i, j]^2 / (2 * diag(Hinv)[j])

Pure VPU elementwise work: one fused pass over a (bn, m) weight tile with
the Hinv diagonal broadcast from a (1, m) row resident in VMEM. The fusion
(square + divide in one kernel) is the TPU analogue of the paper's GPU
elementwise kernel; no HBM round-trip for the intermediate w^2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(w_ref, d_ref, o_ref):
    w = w_ref[...]
    d = d_ref[...]  # (1, m) broadcast row
    o_ref[...] = (w * w) / (2.0 * d)


@functools.partial(jax.jit, static_argnames=("bn",))
def solution_s_scores(w, hinv_diag, bn=128):
    """Eq. (14) scores for w:(n,m), hinv_diag:(m,)."""
    n, m = w.shape
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    d2 = hinv_diag.reshape(1, m)
    return pl.pallas_call(
        _score_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(w, d2)
