"""L1 Pallas kernel: Solution-M 2:4 mask selection (paper Eq. 12).

For every 4-column group the paper enumerates the C(4,2)=6 ways of pruning
2 weights and picks the combination with minimal Eq. (12) loss

    L(a, b) = 1/2 * [w_a w_b] inv(S_ab) [w_a w_b]^T ,
    S_ab    = Hinv[[a,b]][:, [a,b]]   (2x2, closed-form inverse)

using the 4x4 *diagonal blocks* of Hinv (groups interact only through the
later compensation step — the paper's per-group simplification, Sec 4.2.1).

TPU mapping: the 6-combo inner loop is unrolled in-register on the VPU; no
gathers are needed because L2 re-lays Hinv's diagonal blocks out as a dense
(m/4, 4, 4) tensor once per layer. Grid is over row tiles; one kernel
invocation consumes a (bn, m) weight tile plus the (m/4, 16) block table
and emits the 0/1 mask tile and the per-group minimal loss.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import COMBOS_2_4


def _mask24_kernel(w_ref, hb_ref, mask_ref, loss_ref):
    bn = w_ref.shape[0]
    m = w_ref.shape[1]
    g = m // 4
    wg = w_ref[...].reshape(bn, g, 4)
    hb = hb_ref[...].reshape(g, 4, 4)

    losses = []
    for (a, b) in COMBOS_2_4:  # unrolled: 6 combos
        s11 = hb[:, a, a][None, :]
        s22 = hb[:, b, b][None, :]
        s12 = hb[:, a, b][None, :]
        det = s11 * s22 - s12 * s12
        wa = wg[:, :, a]
        wb = wg[:, :, b]
        losses.append(
            0.5 * (wa * wa * s22 - 2.0 * wa * wb * s12 + wb * wb * s11) / det
        )
    lstack = jnp.stack(losses, axis=0)  # (6, bn, g)
    best = jnp.argmin(lstack, axis=0)  # (bn, g)
    loss_ref[...] = jnp.min(lstack, axis=0)

    # Combo -> 4-lane 0/1 pattern lookup, computed via comparisons (VPU).
    table = jnp.zeros((len(COMBOS_2_4), 4), dtype=jnp.float32)
    for ci, (a, b) in enumerate(COMBOS_2_4):
        table = table.at[ci, a].set(1.0).at[ci, b].set(1.0)
    mask = table[best]  # (bn, g, 4)
    mask_ref[...] = mask.reshape(bn, m)


@functools.partial(jax.jit, static_argnames=("bn",))
def solution_m_mask24(w, hinv_blocks, bn=128):
    """2:4 Solution-M mask for w:(n,m), hinv_blocks:(m//4,4,4).

    Returns (mask, group_loss): mask (n,m) 1.0=pruned (exactly 2 per group),
    group_loss (n, m//4) minimal Eq. (12) loss per group.
    """
    n, m = w.shape
    g = m // 4
    bn = min(bn, n)
    assert n % bn == 0 and m % 4 == 0, (n, m, bn)
    hb_flat = hinv_blocks.reshape(g, 16)
    return pl.pallas_call(
        _mask24_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((g, 16), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, g), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m), jnp.float32),
            jax.ShapeDtypeStruct((n, g), jnp.float32),
        ],
        interpret=True,
    )(w, hb_flat)


def extract_diag_blocks4(hinv):
    """(m,m) -> (m//4,4,4) diagonal 4x4 blocks (L2-side re-layout)."""
    m = hinv.shape[0]
    g = m // 4
    return hinv.reshape(g, 4, g, 4)[jnp.arange(g), :, jnp.arange(g), :]
