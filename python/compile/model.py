"""L2: JAX pruning graphs composing the L1 Pallas kernels (build time only).

Each public function here is a fixed-shape, jit-able graph that `aot.py`
lowers to HLO text for the Rust runtime. The graphs implement the paper's
Algorithm 1 for one linear layer with S=all (whole-matrix block); the Rust
native path (`rust/src/prune/`) additionally implements the S<all blockwise
sweep with identical math (see DESIGN.md SS7 delta #1).

Naming follows the paper: Solution S = diagonal approximation (SparseGPT-
like), Solution M = full-interaction optimal solution (ours). A method
"XY" uses X for the pruning mask and Y for the compensation.

Memory note: the Eq. (13) compensation is evaluated as a scatter + one
dense GEMM  dw = -scatter(lambda) @ Hinv  rather than gathering the (n,k,m)
row-bundle of Hinv, so peak memory stays O(n*m + n*k^2) and the update runs
on the MXU.
"""

import functools

import jax
import jax.numpy as jnp

from .linalg import batched_spd_solve, cholesky_upper, spd_inverse
from .kernels.hessian import hessian_xtx
from .kernels.mask24 import extract_diag_blocks4, solution_m_mask24
from .kernels.score import solution_s_scores


# ---------------------------------------------------------------------------
# Hessian accumulation (calibration stream)
# ---------------------------------------------------------------------------

def hessian_update(x, h):
    """One calibration chunk: h + 2 * X^T X  (X:(T,m), h:(m,m))."""
    return (h + hessian_xtx(x),)


def hessian_finalize(h, gamma):
    """Remark 4.1 dampening + inversion: returns Hinv = (H + g*mean(diag)*I)^-1.

    gamma is a traced scalar input so one artifact serves every dampening
    ratio in the Fig. A1 ablation.
    """
    m = h.shape[0]
    damp = gamma * jnp.mean(jnp.diag(h))
    hd = h + damp * jnp.eye(m, dtype=h.dtype)
    # Cholesky-based symmetric inverse (pure-HLO; see linalg.py).
    return (spd_inverse(hd),)


# ---------------------------------------------------------------------------
# Eq. (13) Solution-M compensation (batched over rows, uniform k)
# ---------------------------------------------------------------------------

def _compensate(w, idx, hinv):
    """Optimal MRP compensation for per-row pruned column sets idx:(n,k).

    Returns (w_new, pred_loss): w_new exactly zero at pruned entries,
    pred_loss = Eq. (12) total over all rows.
    """
    n, m = w.shape
    k = idx.shape[1]

    # sub[r] = Hinv[idx_r, idx_r]  (n,k,k); rhs[r] = w[r, idx_r]  (n,k)
    sub = jax.vmap(lambda p: hinv[p][:, p])(idx)
    rhs = jnp.take_along_axis(w, idx, axis=1)

    # lambda* = inv(sub) @ rhs  (Eq. 10 with the 1/2, absorbed signs);
    # pure-HLO batched Cholesky solve (linalg.py) instead of LAPACK.
    lam = batched_spd_solve(sub, rhs)  # (n,k)

    # dw = -scatter(lam) @ Hinv  (Eq. 13, Hinv symmetric)
    lam_full = jnp.zeros((n, m), w.dtype)
    lam_full = jnp.put_along_axis(lam_full, idx, lam, axis=1, inplace=False)
    w_new = w - lam_full @ hinv

    # Exact zeros at pruned entries (theory guarantees it; enforce exactly).
    w_new = jnp.put_along_axis(w_new, idx, jnp.zeros_like(lam), axis=1, inplace=False)

    pred_loss = 0.5 * jnp.sum(lam * rhs)
    return w_new, pred_loss


# ---------------------------------------------------------------------------
# Full prune graphs (one linear layer, S=all)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def prune_unstructured_sm(w, hinv, k):
    """Unstructured SM: Eq. (14) per-row top-k mask + Eq. (13) compensation."""
    # argsort instead of lax.top_k: the `topk` HLO instruction is newer
    # than the xla_extension 0.5.1 parser.
    scores = solution_s_scores(w, jnp.diag(hinv))
    idx = jnp.sort(jnp.argsort(scores, axis=1)[:, :k], axis=1)
    w_new, loss = _compensate(w, idx, hinv)
    return w_new, loss


@jax.jit
def prune_24_sm(w, hinv):
    """2:4 SM: Eq. (14) scores, 2 smallest per 4-group, Eq. (13) comp."""
    n, m = w.shape
    g = m // 4
    scores = solution_s_scores(w, jnp.diag(hinv)).reshape(n, g, 4)
    local = jnp.argsort(scores, axis=2)[:, :, :2]  # (n,g,2) within group
    idx = (local + (jnp.arange(g) * 4)[None, :, None]).reshape(n, m // 2)
    idx = jnp.sort(idx, axis=1)
    w_new, loss = _compensate(w, idx, hinv)
    return w_new, loss


@jax.jit
def prune_24_mm(w, hinv):
    """2:4 MM: Eq. (12) 6-combo group mask (Pallas) + Eq. (13) comp."""
    n, m = w.shape
    hb = extract_diag_blocks4(hinv)
    mask, _ = solution_m_mask24(w, hb)
    # mask has exactly 2 ones per 4-group -> m/2 pruned per row; stable
    # argsort keeps indices ascending among equal keys.
    idx = jnp.sort(jnp.argsort(-mask, axis=1, stable=True)[:, : m // 2], axis=1)
    w_new, loss = _compensate(w, idx, hinv)
    return w_new, loss


@jax.jit
def prune_seq_given_mask(w, mask, hinv):
    """Solution-S (SparseGPT/OBC) sequential compensation for a given mask.

    The paper's Sec. 2.3.2 freezing scheme: sweep columns left->right with
    the upper Cholesky factor U of Hinv (Hinv = U^T U); weights left of the
    cursor stay frozen. Used for the SS and MS method variants.
    """
    u = cholesky_upper(hinv)  # (m, m) upper, pure-HLO (linalg.py)

    def body(j, wcur):
        d = u[j, j]
        err = (wcur[:, j] * mask[:, j]) / d
        upd = jnp.outer(err, u[j])
        # Zero the update strictly left of j (those columns are frozen);
        # u[j, :j] is already zero for an upper factor, so this is exact.
        wcur = wcur - upd
        return wcur.at[:, j].set(jnp.where(mask[:, j] > 0, 0.0, wcur[:, j]))

    w_new = jax.lax.fori_loop(0, w.shape[1], body, w)
    return (w_new,)


@jax.jit
def prune_24_ms(w, hinv):
    """2:4 MS: Eq. (12) group mask + SparseGPT sequential compensation."""
    hb = extract_diag_blocks4(hinv)
    mask, _ = solution_m_mask24(w, hb)
    return prune_seq_given_mask(w, mask, hinv)


# ---------------------------------------------------------------------------
# AOT entry-point registry (name -> (fn, example-args builder))
# ---------------------------------------------------------------------------

def entry_points(n, m, t, k):
    """All exportable graphs for a (n out, m in) layer, calib chunk t."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return {
        "hessian_update": (hessian_update, (s((t, m), f32), s((m, m), f32))),
        "hessian_finalize": (hessian_finalize, (s((m, m), f32), s((), f32))),
        "prune_sm": (
            functools.partial(prune_unstructured_sm, k=k),
            (s((n, m), f32), s((m, m), f32)),
        ),
        "prune_24_sm": (prune_24_sm, (s((n, m), f32), s((m, m), f32))),
        "prune_24_mm": (prune_24_mm, (s((n, m), f32), s((m, m), f32))),
        "prune_24_ms": (prune_24_ms, (s((n, m), f32), s((m, m), f32))),
        "prune_seq": (
            prune_seq_given_mask,
            (s((n, m), f32), s((n, m), f32), s((m, m), f32)),
        ),
    }
