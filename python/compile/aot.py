"""AOT compile path: lower the L2 graphs to HLO text + manifest.

Usage (from python/):  python -m compile.aot --out ../artifacts

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

The artifact set covers the layer shapes of the in-repo demo models (see
shapes below) x the method graphs in model.entry_points. The Rust runtime
reads artifacts/manifest.json, memoizes compiled executables per (file),
and falls back to the native Rust solver for shapes not in the registry.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as L2

# (n_out, m_in, calib_chunk_t): the linear-layer shapes used by the demo
# models in rust/src/model/ plus a small shape for the quickstart example.
DEFAULT_SHAPES = [
    (64, 64, 64),      # quickstart / tests
    (128, 128, 128),   # microllama-s attention
    (256, 128, 128),   # microllama-s mlp up/gate
    (128, 256, 128),   # microllama-s mlp down
    (256, 256, 128),   # microllama-m attention
    (512, 256, 128),   # microllama-m mlp up/gate
    (256, 512, 128),   # microllama-m mlp down
]


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_sig(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--shapes", default="", help="semicolon list n,m,t overriding defaults")
    ap.add_argument("--only", default="", help="comma list of entry names to build")
    args = ap.parse_args(argv)

    shapes = DEFAULT_SHAPES
    if args.shapes:
        shapes = [tuple(int(v) for v in part.split(",")) for part in args.shapes.split(";")]
    only = set(args.only.split(",")) if args.only else None

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "entries": []}
    for (n, m, t) in shapes:
        k = m // 2  # 50% unstructured (the headline sparsity)
        for name, (fn, ex) in L2.entry_points(n, m, t, k).items():
            if only and name not in only:
                continue
            fname = f"{name}_n{n}_m{m}_t{t}.hlo.txt"
            text = to_hlo_text(fn, ex)
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "name": name,
                    "file": fname,
                    "n": n,
                    "m": m,
                    "t": t,
                    "k": k,
                    "inputs": [shape_sig(s) for s in ex],
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
            )
            print(f"wrote {fname} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['entries'])} entries -> {args.out}/manifest.json",
          file=sys.stderr)


if __name__ == "__main__":
    main()
