"""Build-time-only package: L1 Pallas kernels + L2 JAX graphs + AOT export.

Never imported at runtime - the Rust binary consumes artifacts/*.hlo.txt.
"""
